//! Quickstart: launch an in-process parameter-server "cluster", create two
//! tables with *different* consistency models (paper §4.1 allows this),
//! run a few workers, and inspect the metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use bapps::prelude::*;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 2 server shards, 2 client processes × 2 worker threads = P = 4.
    let cfg = SystemConfig::builder()
        .num_server_shards(2)
        .num_client_procs(2)
        .threads_per_proc(2)
        .flush_interval_us(100)
        .build();
    let system = PsSystem::launch(cfg)?;

    // A clock-bounded table (CAP, s = 2)...
    system
        .create_table(TableDesc {
            id: TableId(0),
            num_rows: 64,
            row_width: 8,
            row_kind: RowKind::Dense,
            policy: PolicyConfig::Cap { staleness: 2 },
        })?;
    // ...and a value-bounded one (weak VAP, v_thr = 8) — Figure 1's knob.
    system
        .create_table(TableDesc {
            id: TableId(1),
            num_rows: 64,
            row_width: 8,
            row_kind: RowKind::Sparse,
            policy: PolicyConfig::Vap { v_thr: 8.0, strong: false },
        })?;

    let sums = system
        .run_workers(|ctx| {
            let cap_table = ctx.table(TableId(0));
            let vap_table = ctx.table(TableId(1));
            for clock in 0..20u64 {
                // every worker increments a shared row under each model
                cap_table.inc(RowId(clock % 64), 0, 1.0).unwrap();
                vap_table.inc(RowId(0), 0, 0.5).unwrap();
                // reads go through the consistency gates
                let _ = cap_table.get(RowId(clock % 64), 0).unwrap();
                let _ = vap_table.get(RowId(0), 0).unwrap();
                ctx.clock().unwrap();
            }
            // read-my-writes: this worker's contribution is always visible
            vap_table.get(RowId(0), 0).unwrap()
        })?;

    println!("per-worker final reads of vap[0,0]: {sums:?}");
    println!("(each ≥ its own 10.0 contribution — read-my-writes)");
    println!("\nworker metrics:\n{}", system.metrics_summary());
    println!(
        "\nnetwork: {} msgs, {} bytes",
        system.net_metrics().total_sends(),
        system.net_metrics().bytes_sent()
    );
    system.shutdown()?;
    println!("done.");
    Ok(())
}
