//! The Theorem-1 workload: distributed SGD logistic regression under VAP,
//! with the measured regret compared against the paper's bound
//! `R[X] ≤ σL²√T + (F²/σ)√T + 2σL·v_thr·P·√T`.
//!
//! ```sh
//! cargo run --release --example sgd_logreg            # pure-Rust gradients
//! cargo run --release --example sgd_logreg -- --xla   # Pallas AOT gradients
//! ```

use std::sync::Arc;

use bapps::apps::sgd::{run_sgd, LogRegData, LogRegDataConfig, SgdConfig};
use bapps::config::{PolicyConfig, SystemConfig};
use bapps::consistency::cvap::theorem1_regret_bound;
use bapps::coordinator::PsSystem;
use bapps::runtime::ComputePool;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let xla = std::env::args().any(|a| a == "--xla");

    let system = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(2)
            .flush_interval_us(100)
            .build(),
    )?;
    let p = system.config().num_workers();

    let data = Arc::new(LogRegData::synthetic(&LogRegDataConfig {
        n: 8192,
        d: 64,
        noise: 0.02,
        seed: 13,
    }));
    let zero_loss = data.loss(&vec![0.0; data.d]);

    let v_thr = 4.0f32;
    let iters = 200usize;
    let cfg = SgdConfig {
        iters,
        batch: if xla { 128 } else { 32 }, // the AOT artifact bakes B=128
        policy: PolicyConfig::Vap { v_thr, strong: false },
        lipschitz: 4.0,
        diameter: 4.0,
        eta: None, // Theorem-1 schedule η_t = σ/√t
        use_xla: xla,
        seed: 17,
    };
    let pool = if xla {
        Some(Arc::new(ComputePool::start("artifacts", 1)?))
    } else {
        None
    };

    println!(
        "SGD logistic regression: n={} d={} P={p} policy={} {}",
        data.n(),
        data.d,
        cfg.policy.name(),
        if xla { "[logreg_grad AOT artifact]" } else { "[pure-Rust gradient]" },
    );
    let res = run_sgd(&system, data.clone(), cfg.clone(), pool)?;

    println!("\nresults:");
    println!("  loss(0)      : {zero_loss:.4}");
    println!("  final loss   : {:.4}", res.final_loss);
    println!("  accuracy     : {:.3}", res.accuracy);
    println!("  steps/s      : {:.0}", res.steps_per_sec);
    println!("\nnoisy-view loss f_t(x̃_t) every 20 iters:");
    for (i, l) in res.loss_curve.iter().enumerate() {
        if i % 20 == 0 {
            println!("    t={:>4}: {:.4}", i + 1, l);
        }
    }

    // Regret check: R[X]/T = mean(f_t(x̃_t) − f(x*)) must sit under the
    // Theorem-1 bound divided by T. f(x*) ≈ the planted separator's loss.
    let f_star = data.loss(&data.w_true);
    let t = (iters * p as usize) as u64;
    let regret: f64 =
        res.loss_curve.iter().map(|l| (l - f_star).max(0.0)).sum::<f64>() * p as f64;
    let bound = theorem1_regret_bound(t, cfg.lipschitz, cfg.diameter, v_thr as f64, p);
    println!("\nTheorem-1 check (T = {t}):");
    println!("  measured regret R[X]        : {regret:.1}");
    println!("  bound σL²√T+(F²/σ)√T+2σLvP√T: {bound:.1}");
    println!("  R[X]/T                      : {:.4} (→ 0 as T grows)", regret / t as f64);
    println!("  within bound                : {}", regret <= bound);

    system.shutdown()?;
    Ok(())
}
