//! CI smoke slice of the deterministic-simulation acceptance sweep:
//! 64 pinned seeds per policy under the chaos fault mix (the full
//! 1000+-seed sweep lives in `rust/tests/sim_faults.rs`). Pinned seeds
//! keep failures quotable: re-running the printed seed reproduces the
//! exact schedule.

use bapps::config::PolicyConfig;
use bapps::sim::{sweep, SimConfig};

fn main() {
    let policies = [
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 1 },
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
    ];
    for pol in policies {
        let out = sweep(&SimConfig::default().with_policy(pol), 9000..9064);
        assert!(out.ok(), "policy {:?}:\n{}", pol, out.describe());
        println!("{:?}: {} seeds clean", pol, out.runs);
    }
    println!("sim smoke sweep: all policies clean");
}
