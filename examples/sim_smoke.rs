//! CI smoke slice of the deterministic-simulation acceptance sweep:
//! 64 pinned seeds per policy under the chaos fault mix (the full
//! 1000+-seed sweep lives in `rust/tests/sim_faults.rs`). Pinned seeds
//! keep failures quotable: re-running the printed seed reproduces the
//! exact schedule.
//!
//! With `--crash`, runs the crash-recovery slice instead: 16 pinned
//! seeds per policy with a mid-run shard crash (checkpoint + WAL
//! respawn, heartbeat detection, client resync — the full suite lives
//! in `rust/tests/sim_recovery.rs`).

use bapps::config::PolicyConfig;
use bapps::sim::{sweep, SimConfig};

fn main() {
    let crash = std::env::args().any(|a| a == "--crash");
    let policies = [
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 1 },
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
    ];
    for pol in policies {
        let (base, seeds) = if crash {
            (SimConfig::default().with_policy(pol).with_crash(0, 2_500, 2_000), 9500..9516)
        } else {
            (SimConfig::default().with_policy(pol), 9000..9064)
        };
        let out = sweep(&base, seeds);
        assert!(out.ok(), "policy {:?}:\n{}", pol, out.describe());
        println!("{:?}: {} seeds clean", pol, out.runs);
    }
    if crash {
        println!("sim crash-recovery sweep: all policies clean");
    } else {
        println!("sim smoke sweep: all policies clean");
    }
}
