//! CI smoke slice of the deterministic-simulation acceptance sweep:
//! 64 pinned seeds per policy under the chaos fault mix (the full
//! 1000+-seed sweep lives in `rust/tests/sim_faults.rs`). Pinned seeds
//! keep failures quotable: re-running the printed seed reproduces the
//! exact schedule.
//!
//! With `--crash`, runs the crash-recovery slice instead: 16 pinned
//! seeds per policy with a mid-run shard crash (checkpoint + WAL
//! respawn, heartbeat detection, client resync — the full suite lives
//! in `rust/tests/sim_recovery.rs`).
//!
//! With `--trace`, runs the causal-tracing slice: one traced seed per
//! policy, validating that the exported Perfetto JSON parses, that the
//! span-tree oracle saw a closed batch→net→apply→visible chain for
//! every accepted batch, that the recorder dropped zero spans at the
//! default ring size, and that the export is byte-identical across two
//! runs of the same seed. Writes one representative `trace.json` as a
//! CI artifact.
//!
//! With `--metrics`, runs the observability slice: every sim run's
//! metric snapshot is cross-checked against the oracle's independent
//! wire-fed mirrors, the magnitude-priority ablation is reported, a
//! small production cluster is launched with a live scrape endpoint
//! (blocking-gate choreography touches the wall-clock-only counters),
//! the dead-metric lint asserts that every registered metric name was
//! touched by at least one run, and the per-run snapshots are written
//! to `BENCH_sim.json`.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bapps::config::{NetConfig, PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::metrics::{spawn_reporter, untouched_names_across, Snapshot};
use bapps::sim::{ablate, sweep, Sim, SimConfig, SimReport};
use bapps::table::{RowId, RowKind, TableDesc, TableId};

fn policies() -> [PolicyConfig; 6] {
    [
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 1 },
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
    ]
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--metrics") {
        run_metrics_slice();
        return;
    }
    if args.iter().any(|a| a == "--trace") {
        run_trace_slice();
        return;
    }
    let crash = args.iter().any(|a| a == "--crash");
    for pol in policies() {
        let (base, seeds) = if crash {
            (SimConfig::default().with_policy(pol).with_crash(0, 2_500, 2_000), 9500..9516)
        } else {
            (SimConfig::default().with_policy(pol), 9000..9064)
        };
        let out = sweep(&base, seeds);
        assert!(out.ok(), "policy {:?}:\n{}", pol, out.describe());
        println!("{:?}: {} seeds clean", pol, out.runs);
    }
    if crash {
        println!("sim crash-recovery sweep: all policies clean");
    } else {
        println!("sim smoke sweep: all policies clean");
    }
}

/// One traced seed per policy: parse the Perfetto export, confirm the
/// determinism and zero-drop contracts, and leave `trace.json` behind as
/// the CI artifact.
fn run_trace_slice() {
    let mut artifact: Option<String> = None;
    for pol in policies() {
        let cfg = SimConfig::default().with_policy(pol).with_seed(9042);
        let r = Sim::run_traced(&cfg);
        // The span-tree oracle runs inside the sim: any missing
        // batch→net→apply→visible link or orphan span is a violation.
        assert!(r.ok(), "policy {:?}:\n{}", pol, r.describe());
        let json = r.trace_json.clone().expect("run_traced populates trace_json");
        validate_json(&json).unwrap_or_else(|e| panic!("{:?}: trace.json invalid: {e}", pol));
        assert!(json.starts_with("{\"traceEvents\":["), "{:?}: unexpected envelope", pol);
        assert!(json.contains("\"ph\":\"M\""), "{:?}: no process-name metadata", pol);
        assert!(json.contains("\"ph\":\"X\""), "{:?}: no complete spans", pol);
        assert_eq!(
            r.snapshot.counter_sum("trace_spans_dropped_total"),
            0,
            "{:?}: spans dropped at default ring size",
            pol
        );
        // Byte-identity: the same seed must export the same bytes.
        let again = Sim::run_traced(&cfg);
        assert_eq!(
            again.trace_json.as_deref(),
            Some(json.as_str()),
            "{:?}: trace.json differs across identical runs",
            pol
        );
        let stages = ["\"batch\"", "\"net\"", "\"apply\"", "\"visible\""];
        for st in stages {
            assert!(json.contains(st), "{:?}: no {st} spans in export", pol);
        }
        println!("{:?}: seed 9042 traced, {} bytes, chains closed", pol, json.len());
        if artifact.is_none() {
            artifact = Some(json);
        }
    }
    let json = artifact.unwrap();
    std::fs::write("trace.json", &json).expect("write trace.json");
    println!("trace slice: wrote trace.json ({} bytes)", json.len());
}

/// Minimal JSON well-formedness check (no deps): a recursive-descent
/// scan over the grammar. Returns the error position on failure.
fn validate_json(s: &str) -> Result<(), String> {
    let b = s.as_bytes();
    let mut i = 0usize;
    fn ws(b: &[u8], i: &mut usize) {
        while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
            *i += 1;
        }
    }
    fn value(b: &[u8], i: &mut usize, depth: u32) -> Result<(), String> {
        if depth > 64 {
            return Err("nesting too deep".into());
        }
        ws(b, i);
        match b.get(*i) {
            Some(b'{') => {
                *i += 1;
                ws(b, i);
                if b.get(*i) == Some(&b'}') {
                    *i += 1;
                    return Ok(());
                }
                loop {
                    ws(b, i);
                    string(b, i)?;
                    ws(b, i);
                    if b.get(*i) != Some(&b':') {
                        return Err(format!("expected ':' at {i}"));
                    }
                    *i += 1;
                    value(b, i, depth + 1)?;
                    ws(b, i);
                    match b.get(*i) {
                        Some(b',') => *i += 1,
                        Some(b'}') => {
                            *i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected ',' or '}}' at {i}")),
                    }
                }
            }
            Some(b'[') => {
                *i += 1;
                ws(b, i);
                if b.get(*i) == Some(&b']') {
                    *i += 1;
                    return Ok(());
                }
                loop {
                    value(b, i, depth + 1)?;
                    ws(b, i);
                    match b.get(*i) {
                        Some(b',') => *i += 1,
                        Some(b']') => {
                            *i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected ',' or ']' at {i}")),
                    }
                }
            }
            Some(b'"') => string(b, i),
            Some(b't') => lit(b, i, "true"),
            Some(b'f') => lit(b, i, "false"),
            Some(b'n') => lit(b, i, "null"),
            Some(c) if c.is_ascii_digit() || *c == b'-' => {
                *i += 1;
                while *i < b.len()
                    && matches!(b[*i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                {
                    *i += 1;
                }
                Ok(())
            }
            _ => Err(format!("unexpected byte at {i}")),
        }
    }
    fn string(b: &[u8], i: &mut usize) -> Result<(), String> {
        if b.get(*i) != Some(&b'"') {
            return Err(format!("expected string at {i}"));
        }
        *i += 1;
        while let Some(&c) = b.get(*i) {
            match c {
                b'"' => {
                    *i += 1;
                    return Ok(());
                }
                b'\\' => *i += 2,
                _ => *i += 1,
            }
        }
        Err("unterminated string".into())
    }
    fn lit(b: &[u8], i: &mut usize, word: &str) -> Result<(), String> {
        if b[*i..].starts_with(word.as_bytes()) {
            *i += word.len();
            Ok(())
        } else {
            Err(format!("bad literal at {i}"))
        }
    }
    value(b, &mut i, 0)?;
    ws(b, &mut i);
    if i != b.len() {
        return Err(format!("trailing bytes at {i}"));
    }
    Ok(())
}

/// Registry numbers must agree exactly with the oracle's independent
/// mirrors — the same invariants `rust/tests/metrics_oracle.rs` asserts,
/// enforced here on every run of the slice.
fn cross_check(r: &SimReport) {
    assert!(r.ok(), "{}", r.describe());
    assert_eq!(
        r.snapshot.hist_max("client_read_staleness_clocks"),
        r.oracle_max_staleness as u64,
        "{} seed {}: staleness histogram max != oracle mirror",
        r.policy,
        r.seed
    );
    assert_eq!(
        r.snapshot.gauge_max("client_update_magnitude_max"),
        r.oracle_u_obs as f64,
        "{} seed {}: magnitude gauge != oracle u_obs",
        r.policy,
        r.seed
    );
    assert_eq!(
        r.snapshot.counter_sum("shard_pushes_applied_total"),
        r.oracle_applied_batches,
        "{} seed {}: shard apply count != oracle batch mirror",
        r.policy,
        r.seed
    );
    if r.crashes == 0 {
        assert_eq!(
            r.snapshot.counter_sum("client_pushes_retransmitted_total"),
            0,
            "{} seed {}: retransmissions on a crash-free run",
            r.policy,
            r.seed
        );
    }
}

/// One serialized run for `BENCH_sim.json`.
struct BenchRun {
    policy: String,
    seed: u64,
    crash: bool,
    snapshot: Snapshot,
}

fn run_metrics_slice() {
    let mut runs: Vec<BenchRun> = Vec::new();

    // 1. Clean chaos slice, every policy: cross-check each run.
    for pol in policies() {
        for seed in 9000..9008u64 {
            let r = Sim::run(&SimConfig::default().with_policy(pol).with_seed(seed));
            cross_check(&r);
            runs.push(BenchRun { policy: r.policy, seed, crash: false, snapshot: r.snapshot });
        }
    }
    println!("metrics slice: {} clean runs cross-checked", runs.len());

    // 2. Crash slice: scan seeds until every recovery-path metric has
    //    fired at least once (retransmission, pull re-issue, WAL replay,
    //    epoch fence, dedup, heartbeat miss, respawn), with a hard cap.
    //    Deterministic runs make the scan itself reproducible.
    let crash_policies =
        [PolicyConfig::Ssp { staleness: 1 }, PolicyConfig::Vap { v_thr: 2.0, strong: false }];
    let recovery_names = [
        "client_pushes_retransmitted_total",
        "client_pull_retries_total",
        "shard_wal_replayed_total",
        "shard_epoch_bumps_total",
        "shard_pushes_deduped_total",
        "shard_pushes_fenced_total",
        "coord_heartbeat_rtt_us",
        "coord_heartbeat_misses_total",
        "coord_shard_respawns_total",
    ];
    let mut crash_runs = 0u64;
    for seed in 9500..9620u64 {
        let pol = crash_policies[(seed % 2) as usize];
        let cfg =
            SimConfig::default().with_policy(pol).with_seed(seed).with_crash(0, 2_000, 1_000);
        let r = Sim::run(&cfg);
        cross_check(&r);
        crash_runs += 1;
        runs.push(BenchRun { policy: r.policy, seed, crash: true, snapshot: r.snapshot });
        let dead = untouched_names_across(runs.iter().map(|b| &b.snapshot));
        if recovery_names.iter().all(|n| !dead.iter().any(|d| d.as_str() == *n)) {
            break;
        }
    }
    let dead = untouched_names_across(runs.iter().map(|b| &b.snapshot));
    let missed: Vec<&str> = recovery_names
        .iter()
        .copied()
        .filter(|n| dead.iter().any(|d| d.as_str() == *n))
        .collect();
    assert!(missed.is_empty(), "crash scan exhausted without touching: {missed:?}");
    println!("crash slice: {crash_runs} runs, all recovery counters exercised");

    // 3. Magnitude-priority ablation (E6): same seeds, drain order
    //    flipped, partial drains so the order is observable. Deltas are
    //    reported, not asserted — correctness must hold either way.
    let ab_base = SimConfig::default().with_policy(PolicyConfig::Vap { v_thr: 1.0, strong: false });
    let ablation = ablate(&ab_base, 9000..9006);
    assert!(ablation.ok(), "ablation arm violated a bound:\n{}", ablation.describe());
    println!("ablation (priority on vs off):\n{}", ablation.describe());

    // 4. Production mini-run: real threads, real wall clock, live scrape
    //    endpoint. The choreography forces a BSP read block (a fast
    //    worker reads ahead of a sleeping sibling) and VAP write blocks
    //    (pending mass crosses v_thr), touching the blocking-path
    //    counters the virtual-time sim can never reach.
    let prod_snapshot = run_production_slice();

    // 5. Dead-metric lint: every registered metric name must have been
    //    touched by at least one run in this process.
    let mut all: Vec<&Snapshot> = runs.iter().map(|b| &b.snapshot).collect();
    all.push(&prod_snapshot);
    let dead = untouched_names_across(all);
    assert!(dead.is_empty(), "dead metrics — registered but never touched by any slice: {dead:?}");
    println!("dead-metric lint: every registered metric name was touched");

    // 6. Emit BENCH_sim.json (sim snapshots are deterministic; the
    //    production snapshot is wall-clocked and therefore omitted).
    let mut out = String::from("{\n  \"bench\": \"sim_metrics_smoke\",\n");
    out.push_str(&format!("  \"runs\": {},\n  \"crash_runs\": {crash_runs},\n", runs.len()));
    out.push_str(&format!(
        "  \"ablation\": {{\"on\": {}, \"off\": {}}},\n",
        ablation_arm_json(&ablation.on),
        ablation_arm_json(&ablation.off)
    ));
    out.push_str("  \"snapshots\": [\n");
    for (i, b) in runs.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "    {{\"policy\": \"{}\", \"seed\": {}, \"crash\": {}, \"metrics\": {}}}",
            b.policy,
            b.seed,
            b.crash,
            b.snapshot.render_json().replace('\n', "")
        ));
    }
    out.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_sim.json", &out).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json ({} bytes, {} snapshots)", out.len(), runs.len());
}

fn ablation_arm_json(a: &bapps::sim::AblationArm) -> String {
    format!(
        "{{\"priority\": {}, \"runs\": {}, \"write_blocks\": {}, \"write_blocked_us\": {}, \
         \"egress_reorders\": {}}}",
        a.priority, a.runs, a.write_blocks, a.write_blocked_us, a.egress_reorders
    )
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect scrape endpoint");
    s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn run_production_slice() -> Snapshot {
    let cfg = SystemConfig::builder()
        .num_server_shards(2)
        .num_client_procs(2)
        .threads_per_proc(1)
        .net(NetConfig { latency_us: 50, bandwidth_bps: 0, jitter_us: 0, seed: 0x5EED })
        .flush_interval_us(100)
        .wait_timeout_ms(20_000)
        .heartbeat_interval_us(5_000)
        .heartbeat_deadline_us(1_000_000)
        .metrics_listen("127.0.0.1:0")
        .build();
    let sys = PsSystem::launch(cfg).expect("launch");
    let hub = sys.metrics_registry();
    let reports = Arc::new(AtomicU64::new(0));
    let r_reports = reports.clone();
    let reporter = spawn_reporter(hub.clone(), Duration::from_millis(10), move |_| {
        r_reports.fetch_add(1, Ordering::Relaxed);
    });

    let bsp = TableDesc {
        id: TableId(0),
        num_rows: 8,
        row_width: 2,
        row_kind: RowKind::Dense,
        policy: PolicyConfig::Bsp,
    };
    let vap = TableDesc {
        id: TableId(1),
        num_rows: 8,
        row_width: 2,
        row_kind: RowKind::Dense,
        policy: PolicyConfig::Vap { v_thr: 1.0, strong: false },
    };
    sys.create_table(bsp).unwrap();
    sys.create_table(vap).unwrap();

    sys.run_workers(|ctx| {
        let slow = ctx.worker_id().0 == 1;
        let b = ctx.table(TableId(0));
        let v = ctx.table(TableId(1));
        for _ in 0..3 {
            if slow {
                // The sibling worker reaches its BSP read first and must
                // block on this worker's missing clock tick.
                std::thread::sleep(Duration::from_millis(20));
            }
            b.inc(RowId(0), 0, 1.0).unwrap();
            ctx.clock().unwrap();
            b.get(RowId(0), 0).unwrap();
            // Pending mass 0.9 → 1.8 crosses max(v_thr, u) = 1.0: the
            // write gate blocks until visibility acks drain it.
            for _ in 0..6 {
                v.inc(RowId(1), 0, 0.9).unwrap();
            }
        }
    })
    .expect("production choreography");

    let addr = sys.metrics_addr().expect("metrics endpoint requested at launch");
    let text = http_get(addr, "/metrics");
    assert!(text.starts_with("HTTP/1.1 200 OK"), "scrape failed: {text}");
    assert!(text.contains("# TYPE client_read_blocks_total counter"), "missing type line");
    assert!(text.contains("net_sends_total"), "missing net counters");
    let json = http_get(addr, "/metrics.json");
    assert!(json.contains("\"client_gets_total\""), "JSON scrape missing counters: {json}");

    reporter.shutdown();
    let snap = hub.snapshot();
    sys.shutdown().expect("shutdown");
    assert!(reports.load(Ordering::Relaxed) >= 1, "reporter never fired");
    assert!(
        snap.counter_sum("client_read_blocks_total") > 0,
        "choreography never blocked a BSP read"
    );
    assert!(
        snap.counter_sum("client_write_blocks_total") > 0,
        "choreography never blocked a VAP write"
    );
    println!(
        "production slice: scraped /metrics and /metrics.json at {addr}, {} reporter ticks",
        reports.load(Ordering::Relaxed)
    );
    snap
}
