//! The paper's §5 evaluation: LDA topic modeling on a 20News-scale corpus
//! under **weak VAP**, printing Table 1 (corpus statistics) and the
//! throughput/convergence summary.
//!
//! ```sh
//! cargo run --release --example lda_20news            # scaled corpus
//! cargo run --release --example lda_20news -- --full  # full Table-1 scale
//! cargo run --release --example lda_20news -- --xla   # L1 kernel inner loop
//! ```

use std::sync::Arc;

use bapps::apps::lda::{run_lda, Corpus, LdaConfig, SyntheticCorpusConfig};
use bapps::config::{PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::runtime::ComputePool;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let xla = args.iter().any(|a| a == "--xla");

    // Table 1 — printed from the generator config the run will use.
    let corpus_cfg = if full {
        SyntheticCorpusConfig::news20()
    } else {
        SyntheticCorpusConfig::news20_scaled(16)
    };
    println!("generating corpus (seed {})...", corpus_cfg.seed);
    let corpus = Arc::new(Corpus::synthetic(&corpus_cfg));
    println!("\nTable 1 — summary statistics of the corpus used in LDA:");
    println!("{}\n", corpus.stats());

    // The paper: 8 workers/machine; we use 8 workers in 2 processes.
    let system = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(4)
            .flush_interval_us(100)
            .build(),
    )?;

    // K scaled down from the paper's 2000 (see DESIGN.md §3); policy is
    // the paper's: weak VAP.
    let lda_cfg = LdaConfig {
        num_topics: if full { 2000 } else { 100 },
        alpha: 0.1,
        beta: 0.01,
        sweeps: if full { 2 } else { 5 },
        policy: PolicyConfig::Vap { v_thr: 8.0, strong: false },
        seed: 7,
        use_xla: xla,
    };
    // The AOT artifact bakes K=128; --xla requires a matching topic count.
    let lda_cfg = if xla { LdaConfig { num_topics: 128, ..lda_cfg } } else { lda_cfg };
    let pool = if xla {
        Some(Arc::new(ComputePool::start("artifacts", 1)?))
    } else {
        None
    };

    println!(
        "running LDA: K={} sweeps={} P={} policy={} {}",
        lda_cfg.num_topics,
        lda_cfg.sweeps,
        system.config().num_workers(),
        lda_cfg.policy.name(),
        if xla { "[Pallas kernel inner loop]" } else { "[pure-Rust inner loop]" },
    );
    let res = run_lda(&system, corpus, lda_cfg, pool)?;

    println!("\nresults:");
    println!("  tokens processed : {}", res.tokens_processed);
    println!("  wall time        : {:.2} s", res.wall_secs);
    println!("  throughput       : {:.0} tokens/s", res.tokens_per_sec);
    println!("  convergence (mean log p(topic) per sweep, rising = better):");
    for (i, ll) in res.loglik_curve.iter().enumerate() {
        println!("    sweep {:>2}: {:+.4}", i + 1, ll);
    }
    println!("\n{}", system.metrics_summary());
    system.shutdown()?;
    Ok(())
}
