//! End-to-end validation (DESIGN.md E8): train a transformer LM
//! data-parallel through the parameter server for a few hundred steps on
//! synthetic bigram data and log the loss curve.
//!
//! Every layer composes here: the L1 Pallas matmul kernels (custom-VJP,
//! so backward is Pallas too) are inlined into the L2 jax train step,
//! AOT-lowered to `artifacts/transformer_step.hlo.txt`, loaded by the
//! Rust PJRT runtime, and driven by PS workers whose parameter reads and
//! gradient writes go through a bounded-asynchronous consistency model.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_transformer
//! cargo run --release --example train_transformer -- --steps 300 --policy vap:8
//! ```

use std::sync::Arc;

use bapps::apps::transformer::{train, TrainConfig, TransformerSpec};
use bapps::config::{PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::runtime::ComputePool;

fn arg<T: std::str::FromStr>(name: &str, default: T) -> T {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: usize = arg("--steps", 300);
    let eta: f32 = arg("--eta", 0.25);
    let policy_spec: String = arg("--policy", "ssp:1".to_string());
    let policy = PolicyConfig::parse(&policy_spec)?;

    let spec = Arc::new(
        TransformerSpec::load("artifacts")
            .map_err(|e| format!("{e} — run `make artifacts` first"))?,
    );
    println!(
        "transformer LM: {} params (vocab={} d={} layers={} heads={} seq={} batch={})",
        spec.num_params(),
        spec.vocab,
        spec.d_model,
        spec.n_layers,
        spec.n_heads,
        spec.seq_len,
        spec.batch
    );
    println!("(scaled from the 100M-class target for CPU budget — DESIGN.md §3)");

    // Data-parallel over 4 workers; a 2-thread PJRT pool keeps steps
    // overlapping without oversubscribing the CPU.
    let system = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(2)
            .flush_interval_us(200)
            .wait_timeout_ms(300_000)
            .build(),
    )?;
    let pool = Arc::new(ComputePool::start("artifacts", 2)?);

    println!("training {steps} steps/worker, eta={eta}, policy={}...", policy.name());
    let vocab = spec.vocab;
    let res = train(
        &system,
        spec.clone(),
        pool,
        TrainConfig { steps, eta, policy, seed: 1234, log_every: 10 },
    )?;

    println!("\nloss curve (mean over workers, every 10 steps):");
    for (i, l) in res.loss_curve.iter().enumerate() {
        if i % 10 == 0 || i + 1 == res.loss_curve.len() {
            println!("  step {:>4}: {:.4}", i, l);
        }
    }
    let first = res.loss_curve.first().copied().unwrap_or(0.0);
    let last = res.loss_curve.last().copied().unwrap_or(0.0);
    println!("\nfirst loss {first:.4} → last loss {last:.4}");
    println!("steps/s (aggregate): {:.2}; wall {:.1}s", res.steps_per_sec, res.wall_secs);
    println!(
        "uniform baseline ln(V) = {:.4}; bigram entropy floor ln(4) = {:.4}",
        (vocab as f64).ln(),
        (4f64).ln()
    );
    system.shutdown()?;
    Ok(())
}
