//! E8 — serve-path benchmark: push-apply throughput and pull-reply cost
//! through a single `ServerShard`, swept over `apply_threads` ∈ {1, 2, 4}.
//!
//! The shard is driven synchronously through [`ServerShard::handle`] with a
//! null transport swallowing replies, so the numbers isolate the apply path:
//! WAL encode + striped-store apply (+ forwarded-prefix apply + fan-out
//! construction), with no bus, client, or scheduler noise. Per-batch handle
//! latency is recorded exactly (no histogram buckets) and summarized as
//! p50/p99; rows/sec counts applied updates per wall-clock second.
//!
//! Emits `BENCH_serve.json` (CI uploads it next to `BENCH_sim.json`).
//! Thread-count *speedups* are only meaningful on multi-core runners; the
//! JSON records whatever the host measured.
//!
//! The single-threaded run is additionally repeated with span capture
//! disabled, giving the causal tracer's overhead as a throughput ratio
//! (`trace_overhead.rows_per_sec_ratio`; the acceptance bound is < 5%
//! regression with tracing on).

use std::sync::Arc;
use std::time::Instant;

use bapps::comm::msg::{Msg, Payload, PushBatch};
use bapps::comm::{NetSender, Transport};
use bapps::config::PolicyConfig;
use bapps::error::Result;
use bapps::metrics::{NetMetrics, Registry};
use bapps::server::{MemPersistence, ServerShard, ShardOptions, TableRegistry};
use bapps::table::{RowId, RowKind, RowUpdate, TableDesc, TableId};
use bapps::trace::{TraceClock, TraceCtx, TraceRecorder, DEFAULT_RING_SLOTS};
use bapps::types::{NodeId, ProcId, ShardId, WorkerId};

/// Swallows every send: the bench measures the shard's handler cost, not
/// delivery. Fan-out construction (the per-proc `Arc` bumps in `forward`)
/// still happens, so the clone-free path is what's being timed.
struct NullTransport {
    metrics: Arc<NetMetrics>,
}

impl Transport for NullTransport {
    fn send(&self, _msg: Msg) -> Result<()> {
        Ok(())
    }
    fn metrics(&self) -> Arc<NetMetrics> {
        Arc::clone(&self.metrics)
    }
}

const TABLE: TableId = TableId(0);
const ROWS: u64 = 4096;
const WIDTH: u32 = 64;
/// Updates per push batch. Large enough that fanning one batch across lanes
/// amortizes the pool's dispatch + barrier; the default client batcher caps
/// in the same range.
const BATCH: usize = 512;
const WARMUP_BATCHES: usize = 16;
const BATCHES: usize = 192;
const PULLS: usize = 20_000;

/// Dense-gradient push workload: `BATCHES` batches of `BATCH` row updates,
/// rows striding over the table so every store stripe stays hot. Built once
/// and shared (`Arc` clones) across thread-count runs so each run applies
/// byte-identical input.
fn build_batches() -> Vec<PushBatch> {
    let mut next_row = 0u64;
    (0..WARMUP_BATCHES + BATCHES)
        .map(|b| {
            let updates: Vec<(RowId, RowUpdate)> = (0..BATCH)
                .map(|i| {
                    let row = RowId(next_row % ROWS);
                    next_row += 1;
                    let seed = (b * BATCH + i) as f32;
                    let grad: Vec<f32> =
                        (0..WIDTH).map(|c| (seed + c as f32) * 1e-4 - 0.01).collect();
                    (row, RowUpdate::Dense(grad))
                })
                .collect();
            PushBatch {
                table: TABLE,
                origin: ProcId(0),
                batch_id: b as u64,
                updates: Arc::new(updates),
                clock: 1,
                epoch: 0,
                // Real minted contexts: the bench must time the span
                // record path, not the `is_none()` early-outs.
                trace: TraceCtx::mint(1, 0, b as u64, 0, 0),
            }
        })
        .collect()
}

struct RunStats {
    apply_threads: u32,
    rows_per_sec: f64,
    push_p50_us: f64,
    push_p99_us: f64,
    pull_p50_us: f64,
    pull_p99_us: f64,
}

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() as f64 - 1.0) * p).round() as usize;
    sorted_us[idx]
}

fn run_one(apply_threads: u32, batches: &[PushBatch], span_capture: bool) -> RunStats {
    let registry = Arc::new(TableRegistry::default());
    registry
        .insert(TableDesc {
            id: TABLE,
            num_rows: ROWS,
            row_width: WIDTH,
            row_kind: RowKind::Dense,
            policy: PolicyConfig::BestEffort,
        })
        .unwrap();
    let net = NetSender::from_transport(Arc::new(NullTransport {
        metrics: Arc::new(NetMetrics::default()),
    }));
    let mut opts = ShardOptions::new(Arc::new(MemPersistence::new()));
    // Never checkpoint: the WAL encode stays in the measured path (it is
    // part of every live push), but snapshot assembly is not.
    opts.checkpoint_every = 0;
    opts.apply_threads = apply_threads;
    // Registry-backed recorder so the A/B includes the full production
    // record path: ring write + lazy stage-histogram update.
    let trace = Arc::new(TraceRecorder::with_registry(
        false,
        Arc::new(Registry::new()),
        TraceClock::wall(),
        DEFAULT_RING_SLOTS,
    ));
    trace.set_span_capture(span_capture);
    let mut shard = ServerShard::with_options(ShardId(0), 1, registry, net, trace, opts);

    // --- push phase ---
    let mut push_us: Vec<f64> = Vec::with_capacity(BATCHES);
    let mut measured_t0 = Instant::now();
    for (i, b) in batches.iter().enumerate() {
        if i == WARMUP_BATCHES {
            measured_t0 = Instant::now();
        }
        let t0 = Instant::now();
        shard.handle(Msg {
            src: NodeId::Client(ProcId(0)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::PushUpdates(b.clone()),
        });
        if i >= WARMUP_BATCHES {
            push_us.push(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
    let push_secs = measured_t0.elapsed().as_secs_f64();
    let rows_per_sec = (BATCHES * BATCH) as f64 / push_secs;

    // --- pull phase (forwarded-prefix reads; replies share the CoW row) ---
    let mut pull_us: Vec<f64> = Vec::with_capacity(PULLS);
    for i in 0..PULLS {
        let t0 = Instant::now();
        shard.handle(Msg {
            src: NodeId::Client(ProcId(0)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::PullRow {
                table: TABLE,
                row: RowId(i as u64 % ROWS),
                needed_clock: 0,
                worker: WorkerId(0),
                trace: TraceCtx::mint(2, 0, i as u64, 0, 0),
            },
        });
        pull_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }

    push_us.sort_by(f64::total_cmp);
    pull_us.sort_by(f64::total_cmp);
    RunStats {
        apply_threads,
        rows_per_sec,
        push_p50_us: percentile(&push_us, 0.50),
        push_p99_us: percentile(&push_us, 0.99),
        pull_p50_us: percentile(&pull_us, 0.50),
        pull_p99_us: percentile(&pull_us, 0.99),
    }
}

fn main() {
    let batches = build_batches();
    println!("# E8 — serve-path bench: {BATCHES} batches × {BATCH} updates × {WIDTH} cols\n");
    println!("| threads |     rows/s | push p50 us | push p99 us | pull p50 us | pull p99 us |");
    println!("|---------|------------|-------------|-------------|-------------|-------------|");

    let mut runs: Vec<RunStats> = Vec::new();
    for threads in [1u32, 2, 4] {
        let s = run_one(threads, &batches, true);
        println!(
            "| {:>7} | {:>10.0} | {:>11.1} | {:>11.1} | {:>11.1} | {:>11.1} |",
            s.apply_threads,
            s.rows_per_sec,
            s.push_p50_us,
            s.push_p99_us,
            s.pull_p50_us,
            s.pull_p99_us
        );
        runs.push(s);
    }

    // Tracer overhead A/B at threads = 1: same workload with span capture
    // off. Ratio < 1 means capture cost; the acceptance bound is ≥ 0.95.
    let no_spans = run_one(1, &batches, false);
    let overhead_ratio = runs[0].rows_per_sec / no_spans.rows_per_sec;
    println!(
        "\ntracing on vs off (threads = 1): {:.0} vs {:.0} rows/s (ratio {:.3})",
        runs[0].rows_per_sec, no_spans.rows_per_sec, overhead_ratio
    );

    let base = runs[0].rows_per_sec;
    let cpus = std::thread::available_parallelism().map_or(0, |n| n.get());
    let mut out = String::from("{\n  \"bench\": \"serve_push_pull\",\n");
    out.push_str(&format!(
        "  \"workload\": {{\"rows\": {ROWS}, \"row_width\": {WIDTH}, \"batch\": {BATCH}, \
         \"batches\": {BATCHES}, \"pulls\": {PULLS}}},\n"
    ));
    out.push_str(&format!("  \"host_cpus\": {cpus},\n"));
    out.push_str(&format!(
        "  \"trace_overhead\": {{\"rows_per_sec_on\": {:.0}, \"rows_per_sec_off\": {:.0}, \
         \"rows_per_sec_ratio\": {:.4}}},\n",
        runs[0].rows_per_sec, no_spans.rows_per_sec, overhead_ratio
    ));
    out.push_str("  \"runs\": [\n");
    for (i, s) in runs.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"apply_threads\": {}, \"rows_per_sec\": {:.0}, \"speedup_vs_1\": {:.3}, \
             \"push_p50_us\": {:.2}, \"push_p99_us\": {:.2}, \"pull_p50_us\": {:.2}, \
             \"pull_p99_us\": {:.2}}}{}\n",
            s.apply_threads,
            s.rows_per_sec,
            s.rows_per_sec / base,
            s.push_p50_us,
            s.push_p99_us,
            s.pull_p50_us,
            s.pull_p99_us,
            if i + 1 < runs.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write("BENCH_serve.json", &out).expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json ({} bytes, {} runs)", out.len(), runs.len());
}
