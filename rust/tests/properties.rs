//! Property-based tests (via the in-tree `quickprop` mini-harness) over
//! the coordinator-side invariants: routing, batching, merge algebra,
//! vector clocks, VAP accounting and the visibility tracker.

use bapps::clock::VectorClock;
use bapps::comm::batcher::Batcher;
use bapps::comm::msg::PushBatch;
use bapps::comm::priority::{DrainOrder, UpdateQueue};
use bapps::config::PolicyConfig;
use bapps::consistency::ConsistencyModel;
use bapps::server::VisibilityTracker;
use bapps::table::{RowData, RowId, RowKind, RowUpdate, TableDesc, TableId};
use bapps::types::ProcId;
use bapps::util::quickprop::{forall, sparse_update, vec_f32};
use bapps::util::Rng64;

fn any_desc(rng: &mut Rng64) -> TableDesc {
    TableDesc {
        id: TableId(rng.below(8) as u32),
        num_rows: rng.range(1, 500) as u64,
        row_width: rng.range(1, 64) as u32,
        row_kind: if rng.chance(0.5) { RowKind::Dense } else { RowKind::Sparse },
        policy: PolicyConfig::Cap { staleness: rng.below(4) as u32 },
    }
}

/// Routing: every row maps to exactly one shard, stably, in range.
#[test]
fn prop_routing_total_stable_in_range() {
    forall(300, 0xA001, |rng| {
        let desc = any_desc(rng);
        let shards = rng.range(1, 17) as u32;
        let row = RowId(rng.below(desc.num_rows as usize) as u64);
        let s1 = desc.shard_of(row, shards);
        let s2 = desc.shard_of(row, shards);
        assert_eq!(s1, s2);
        assert!(s1.0 < shards);
    });
}

/// Update algebra: applying a merge of updates equals applying them
/// one-by-one, in any order (associativity + commutativity, paper §2).
#[test]
fn prop_merge_equals_sequential_apply() {
    forall(300, 0xA002, |rng| {
        let width = rng.range(1, 32) as u32;
        let kind = if rng.chance(0.5) { RowKind::Dense } else { RowKind::Sparse };
        let n = rng.range(1, 6);
        let ups: Vec<RowUpdate> = (0..n)
            .map(|_| {
                if rng.chance(0.5) {
                    RowUpdate::Dense(
                        (0..width).map(|_| (rng.f32() * 2.0 - 1.0) * 4.0).collect(),
                    )
                } else {
                    RowUpdate::Sparse(sparse_update(rng, width, 4.0))
                }
            })
            .collect();

        // sequential
        let mut seq = RowData::zeros(kind, width);
        for u in &ups {
            seq.apply(u);
        }
        // merged (in a shuffled order)
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut merged = ups[order[0]].clone();
        for &i in &order[1..] {
            merged.merge(&ups[i]);
        }
        let mut whole = RowData::zeros(kind, width);
        whole.apply(&merged);

        let a = seq.to_dense(width);
        let b = whole.to_dense(width);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "merge mismatch: {a:?} vs {b:?}");
        }
    });
}

/// Batcher: every drained update lands in exactly one batch, routed to
/// its owning shard, with strictly increasing batch ids.
#[test]
fn prop_batcher_partitions_updates() {
    forall(200, 0xA003, |rng| {
        let desc = any_desc(rng);
        let shards = rng.range(1, 9) as u32;
        let max_batch = rng.range(1, 50);
        let mut batcher = Batcher::new(ProcId(rng.below(4) as u32), max_batch);
        let n_rows = rng.range(1, 60);
        let updates: Vec<(RowId, RowUpdate)> = (0..n_rows)
            .map(|i| (RowId(i as u64 % desc.num_rows), RowUpdate::single(0, rng.f32())))
            .collect();
        let total_in = updates.len();
        let batches = batcher.make_batches(&desc, shards, updates, 1, 0);
        let mut total_out = 0;
        let mut last_id = None;
        for (shard, b) in &batches {
            assert!(b.updates.len() <= max_batch);
            for (row, _) in b.updates.iter() {
                assert_eq!(desc.shard_of(*row, shards), *shard);
                total_out += 1;
            }
            if let Some(prev) = last_id {
                assert!(b.batch_id > prev, "ids must increase");
            }
            last_id = Some(b.batch_id);
        }
        assert_eq!(total_in, total_out);
    });
}

/// UpdateQueue: drains preserve total mass per (row, col) regardless of
/// the drain order policy and chunk sizes.
#[test]
fn prop_update_queue_conserves_mass() {
    forall(200, 0xA004, |rng| {
        let order = if rng.chance(0.5) { DrainOrder::Fifo } else { DrainOrder::Magnitude };
        let mut q = UpdateQueue::new(order);
        let mut expected: std::collections::HashMap<(u64, u32), f32> =
            std::collections::HashMap::new();
        for _ in 0..rng.range(1, 80) {
            let row = rng.below(8) as u64;
            let col = rng.below(4) as u32;
            let d = (rng.f32() * 2.0 - 1.0) * 3.0;
            q.push(RowId(row), RowUpdate::single(col, d));
            *expected.entry((row, col)).or_insert(0.0) += d;
        }
        let mut got: std::collections::HashMap<(u64, u32), f32> =
            std::collections::HashMap::new();
        while !q.is_empty() {
            for (row, u) in q.drain(rng.range(1, 5)) {
                for (c, d) in u.iter_nonzero() {
                    *got.entry((row.0, c)).or_insert(0.0) += d;
                }
            }
        }
        for (k, v) in &expected {
            let g = got.get(k).copied().unwrap_or(0.0);
            assert!((g - v).abs() < 1e-3, "mass mismatch at {k:?}: {g} vs {v}");
        }
    });
}

/// Vector clock: min/max/skew are consistent with a model map under an
/// arbitrary tick sequence.
#[test]
fn prop_vector_clock_matches_model() {
    forall(200, 0xA005, |rng| {
        let n = rng.range(1, 10);
        let mut vc = VectorClock::new(0..n as u32);
        let mut model = vec![0u32; n];
        for _ in 0..rng.range(0, 100) {
            let e = rng.below(n) as u32;
            vc.tick(e);
            model[e as usize] += 1;
        }
        assert_eq!(vc.min_clock(), *model.iter().min().unwrap());
        assert_eq!(vc.max_clock(), *model.iter().max().unwrap());
        assert_eq!(vc.skew(), model.iter().max().unwrap() - model.iter().min().unwrap());
    });
}

/// Write gate: admitted updates never push |pending| past
/// max(u_seen, v_thr) — the quantity the weak-VAP divergence bound rests
/// on (per worker).
#[test]
fn prop_vap_gate_bounds_admitted_mass() {
    forall(300, 0xA006, |rng| {
        let v_thr = 0.5 + rng.f32() * 8.0;
        let model = ConsistencyModel::new(PolicyConfig::Vap { v_thr, strong: false });
        let mut pending = 0.0f32;
        let mut u_seen = 0.0f32;
        for _ in 0..rng.range(1, 50) {
            let d = (rng.f32() * 2.0 - 1.0) * 6.0;
            if !model.write_blocked(pending, d) {
                pending += d;
                u_seen = u_seen.max(d.abs());
                assert!(
                    pending.abs() <= v_thr.max(u_seen) + 1e-4,
                    "pending {pending} exceeded max({u_seen},{v_thr})"
                );
            } else if rng.chance(0.3) {
                // simulate visibility acks releasing some mass
                pending *= rng.f32();
            }
        }
    });
}

/// Visibility tracker: under arbitrary admit/ack interleavings, (a) a
/// batch is reported visible exactly once, after exactly `P` acks; (b)
/// strong-VAP in-flight mass per parameter never exceeds
/// max(u_obs, v_thr) by more than one batch's contribution.
#[test]
fn prop_visibility_tracker_acks() {
    forall(150, 0xA007, |rng| {
        let procs = rng.range(1, 5) as u32;
        let strong = rng.chance(0.5);
        let v_thr = 1.0 + rng.f32() * 4.0;
        let model = ConsistencyModel::new(PolicyConfig::Vap { v_thr, strong });
        let mut vt = VisibilityTracker::new(procs);
        let mut in_flight: Vec<(ProcId, u64)> = Vec::new();
        let mut acks_given: std::collections::HashMap<(u32, u64), u32> =
            std::collections::HashMap::new();
        let mut next_id = vec![0u64; 3];
        let mut visible = 0usize;
        let mut admitted = 0usize;
        for _ in 0..rng.range(1, 60) {
            if rng.chance(0.6) || in_flight.is_empty() {
                let origin = rng.below(3) as u32;
                let b = PushBatch {
                    table: TableId(0),
                    origin: ProcId(origin),
                    batch_id: next_id[origin as usize],
                    updates: std::sync::Arc::new(vec![(
                        RowId(rng.below(3) as u64),
                        RowUpdate::single(0, (rng.f32() * 2.0 - 1.0) * 2.0),
                    )]),
                    clock: 1,
                    epoch: 0,
                    trace: bapps::trace::TraceCtx::NONE,
                };
                next_id[origin as usize] += 1;
                vt.observe(&b);
                if let Some(b) = vt.admit(&model, b) {
                    admitted += 1;
                    in_flight.push((b.origin, b.batch_id));
                }
            } else {
                let i = rng.below(in_flight.len());
                let (origin, id) = in_flight[i];
                let e = acks_given.entry((origin.0, id)).or_insert(0);
                if *e < procs {
                    *e += 1;
                    if vt.ack(origin, id, ProcId(*e - 1)) {
                        visible += 1;
                        in_flight.remove(i);
                        admitted += {
                            let rel = vt.release_ready(&model);
                            for b in &rel {
                                in_flight.push((b.origin, b.batch_id));
                            }
                            rel.len()
                        };
                    } else {
                        assert!(*e < procs, "ack count reached P without visibility");
                    }
                }
            }
        }
        // drain: ack everything remaining
        while let Some((origin, id)) = in_flight.pop() {
            let e = acks_given.entry((origin.0, id)).or_insert(0);
            while *e < procs {
                *e += 1;
                if vt.ack(origin, id, ProcId(*e - 1)) {
                    visible += 1;
                    for b in vt.release_ready(&model) {
                        in_flight.push((b.origin, b.batch_id));
                        admitted += 1;
                    }
                    break;
                }
            }
        }
        assert_eq!(visible, admitted, "every admitted batch becomes visible exactly once");
        assert_eq!(vt.in_flight_count(), 0);
        assert_eq!(vt.held_count(), 0, "no batch may stay held forever");
    });
}

/// Row data survives dense↔sparse round trips of arbitrary updates.
#[test]
fn prop_dense_sparse_equivalence() {
    forall(200, 0xA008, |rng| {
        let width = rng.range(1, 24) as u32;
        let mut dense = RowData::zeros(RowKind::Dense, width);
        let mut sparse = RowData::zeros(RowKind::Sparse, width);
        for _ in 0..rng.range(1, 30) {
            let u = if rng.chance(0.5) {
                RowUpdate::Dense(vec_f32(rng, width as usize, 2.0))
            } else {
                RowUpdate::Sparse(sparse_update(rng, width, 2.0))
            };
            dense.apply(&u);
            sparse.apply(&u);
        }
        let a = dense.to_dense(width);
        let b = sparse.to_dense(width);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-3, "col {i}: dense {x} vs sparse {y}");
        }
    });
}
