//! Metrics-vs-oracle cross-checks.
//!
//! The sim harness runs the whole stack with a virtual-clocked metrics
//! registry, then compares what the registry *observed* against the
//! oracles' independent wire-fed mirrors: max read staleness, max update
//! magnitude, and the count of distinct accepted push batches must agree
//! exactly. Snapshots must also be byte-identical across re-runs of a
//! pinned seed — including crash/recovery runs, where epoch-fenced WAL
//! replay must not double-count applies.

use bapps::config::PolicyConfig;
use bapps::sim::{Sim, SimConfig};

fn policies() -> Vec<PolicyConfig> {
    vec![
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 1 },
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
    ]
}

/// Pinned seed ⇒ byte-identical metric snapshot, in both renderings, for
/// every policy. This is what makes metric numbers quotable in reports:
/// they are a function of `(SimConfig, seed)`, not of the wall clock.
#[test]
fn pinned_seed_gives_byte_identical_snapshots() {
    for pol in policies() {
        let cfg = SimConfig::default().with_policy(pol).with_seed(42);
        let a = Sim::run(&cfg);
        let b = Sim::run(&cfg);
        assert!(a.ok(), "{}", a.describe());
        assert_eq!(
            a.snapshot.render_json(),
            b.snapshot.render_json(),
            "{}: JSON snapshot diverged across identical runs",
            a.policy
        );
        assert_eq!(
            a.snapshot.render_prometheus(),
            b.snapshot.render_prometheus(),
            "{}: Prometheus snapshot diverged across identical runs",
            a.policy
        );
    }
}

/// The registry's observed staleness, update magnitude and apply counts
/// must agree exactly with the oracle's independent mirrors on clean
/// chaos runs.
#[test]
fn registry_agrees_with_oracle_on_clean_runs() {
    for pol in policies() {
        for seed in [42u64, 43, 44] {
            let r = Sim::run(&SimConfig::default().with_policy(pol).with_seed(seed));
            assert!(r.ok(), "{}", r.describe());
            assert_eq!(
                r.snapshot.hist_max("client_read_staleness_clocks"),
                r.oracle_max_staleness as u64,
                "{} seed {seed}: staleness histogram max != oracle mirror",
                r.policy
            );
            assert_eq!(
                r.snapshot.gauge_max("client_update_magnitude_max"),
                r.oracle_u_obs as f64,
                "{} seed {seed}: magnitude gauge != oracle u_obs",
                r.policy
            );
            assert_eq!(
                r.snapshot.counter_sum("shard_pushes_applied_total"),
                r.oracle_applied_batches,
                "{} seed {seed}: shard apply count != oracle batch mirror",
                r.policy
            );
            // No crash was injected, so the recovery counters must be
            // silent: any tick here means spurious resync traffic.
            assert_eq!(
                r.snapshot.counter_sum("client_pushes_retransmitted_total"),
                0,
                "{} seed {seed}: retransmissions on a crash-free run",
                r.policy
            );
            assert_eq!(
                r.snapshot.counter_sum("client_pull_retries_total"),
                0,
                "{} seed {seed}: pull retries on a crash-free run",
                r.policy
            );
            assert_eq!(
                r.snapshot.counter_sum("shard_epoch_bumps_total"),
                0,
                "{} seed {seed}: epoch bump on a crash-free run",
                r.policy
            );
        }
    }
}

/// Crash/recovery runs: epoch-fenced replay must not double-count applies
/// (the apply counter still equals the oracle's dedup'd batch count), the
/// respawn is counted exactly once, and the recovery counters replay
/// deterministically. At least one seed in the scanned window must
/// actually exercise the retransmission path.
#[test]
fn crash_runs_account_recovery_traffic_exactly() {
    let mut saw_retransmit = false;
    for seed in 9500..9520u64 {
        let cfg = SimConfig::default()
            .with_policy(PolicyConfig::Ssp { staleness: 1 })
            .with_seed(seed)
            .with_crash(0, 2_000, 3_000);
        let a = Sim::run(&cfg);
        assert!(a.ok(), "{}", a.describe());
        assert_eq!(a.crashes, 1, "seed {seed}: crash never fired");
        assert_eq!(
            a.snapshot.counter_sum("shard_pushes_applied_total"),
            a.oracle_applied_batches,
            "seed {seed}: replay double-counted applies (or dedup missed)"
        );
        assert_eq!(
            a.snapshot.counter_sum("shard_epoch_bumps_total"),
            1,
            "seed {seed}: exactly one epoch bump per crash"
        );
        assert_eq!(
            a.snapshot.counter_sum("coord_shard_respawns_total"),
            1,
            "seed {seed}: exactly one respawn per crash"
        );
        let retrans = a.snapshot.counter_sum("client_pushes_retransmitted_total");
        if retrans > 0 {
            saw_retransmit = true;
            let b = Sim::run(&cfg);
            assert_eq!(
                retrans,
                b.snapshot.counter_sum("client_pushes_retransmitted_total"),
                "seed {seed}: retransmit count did not replay"
            );
            assert_eq!(
                a.snapshot.counter_sum("client_pull_retries_total"),
                b.snapshot.counter_sum("client_pull_retries_total"),
                "seed {seed}: pull-retry count did not replay"
            );
        }
    }
    assert!(saw_retransmit, "no seed in 9500..9520 exercised the retransmission path");
}

/// Crash snapshots are byte-identical too — recovery instrumentation
/// (WAL replay lengths, fence/dedup counters, heartbeat RTTs) is all
/// virtual-clocked.
#[test]
fn crash_snapshots_are_deterministic() {
    for pol in [PolicyConfig::Ssp { staleness: 1 }, PolicyConfig::Vap { v_thr: 2.0, strong: false }]
    {
        let cfg = SimConfig::default().with_policy(pol).with_seed(21).with_crash(0, 2_000, 3_000);
        let a = Sim::run(&cfg);
        let b = Sim::run(&cfg);
        assert!(a.ok(), "{}", a.describe());
        assert_eq!(
            a.snapshot.render_json(),
            b.snapshot.render_json(),
            "{}: crash snapshot diverged",
            a.policy
        );
    }
}
