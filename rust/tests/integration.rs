//! Cross-layer integration tests: the Rust PJRT runtime executing the
//! JAX/Pallas AOT artifacts, and the apps running on top of both.
//!
//! These tests need `make artifacts` to have run (the Makefile `test`
//! target guarantees it); they self-skip when artifacts are absent so
//! plain `cargo test` still passes in a fresh checkout.

use std::sync::Arc;

use bapps::apps::sgd::{run_sgd, LogRegData, LogRegDataConfig, SgdConfig};
use bapps::apps::transformer::{train, TrainConfig, TransformerSpec};
use bapps::config::{PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::runtime::{ComputePool, Tensor};

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/logreg_grad.hlo.txt").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

/// The AOT logreg gradient must match the pure-Rust implementation on the
/// same minibatch (L1+L2+runtime vs L3 reference — the full-stack
/// correctness check).
#[test]
fn pjrt_logreg_grad_matches_rust_reference() {
    require_artifacts!();
    let pool = ComputePool::start("artifacts", 1).unwrap();
    let data = LogRegData::synthetic(&LogRegDataConfig { n: 128, d: 64, noise: 0.0, seed: 5 });
    let w: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 64.0).collect();
    let idx: Vec<usize> = (0..128).collect();

    // artifact computes SUM grad over B=128
    let out = pool
        .run(
            "logreg_grad",
            vec![
                Tensor::new(w.clone(), vec![64]).unwrap(),
                Tensor::new(data.x.clone(), vec![128, 64]).unwrap(),
                Tensor::new(data.y.clone(), vec![128]).unwrap(),
            ],
        )
        .unwrap();
    let grad_sum = &out[0];
    assert_eq!(grad_sum.shape, vec![64]);
    let loss_sum = out[1].data[0];
    assert!(loss_sum.is_finite() && loss_sum > 0.0);

    let rust_mean = data.grad(&w, &idx); // mean over batch
    for (i, (xla_sum, rust)) in grad_sum.data.iter().zip(&rust_mean).enumerate() {
        let xla_mean = xla_sum / 128.0;
        assert!(
            (xla_mean - rust).abs() < 1e-3 * (1.0 + rust.abs()),
            "grad[{i}]: pjrt {xla_mean} vs rust {rust}"
        );
    }
    pool.shutdown();
}

/// The LDA artifact agrees with the sampler's own probability formula.
#[test]
fn pjrt_lda_probs_match_formula() {
    require_artifacts!();
    let pool = ComputePool::start("artifacts", 1).unwrap();
    // meta bakes B=128, K=128
    let b = 128usize;
    let k = 128usize;
    let n_wk: Vec<f32> = (0..b * k).map(|i| (i % 7) as f32).collect();
    let n_dk: Vec<f32> = (0..k).map(|i| (i % 5) as f32).collect();
    let n_k: Vec<f32> = (0..k).map(|i| 10.0 + (i % 3) as f32).collect();
    let (alpha, beta, vbeta) = (0.1f32, 0.01f32, 534.85f32);
    let out = pool
        .run(
            "lda_topic_probs",
            vec![
                Tensor::new(n_wk.clone(), vec![b, k]).unwrap(),
                Tensor::new(n_dk.clone(), vec![k]).unwrap(),
                Tensor::new(n_k.clone(), vec![k]).unwrap(),
                Tensor::scalar(alpha),
                Tensor::scalar(beta),
                Tensor::scalar(vbeta),
            ],
        )
        .unwrap();
    let probs = &out[0];
    assert_eq!(probs.shape, vec![b, k]);
    for i in 0..b {
        for j in 0..k {
            let want = (n_dk[j] + alpha) * (n_wk[i * k + j] + beta) / (n_k[j] + vbeta);
            let got = probs.data[i * k + j];
            assert!(
                (got - want).abs() < 1e-5 * (1.0 + want.abs()),
                "probs[{i},{j}] {got} vs {want}"
            );
        }
    }
    pool.shutdown();
}

/// Distributed SGD with gradients computed by the AOT artifact converges
/// just like the pure-Rust path (all three layers compose under VAP).
#[test]
fn sgd_through_pjrt_converges() {
    require_artifacts!();
    let system = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(1)
            .flush_interval_us(50)
            .build(),
    )
    .unwrap();
    let data = Arc::new(LogRegData::synthetic(&LogRegDataConfig {
        n: 2048,
        d: 64, // must match the artifact's D
        noise: 0.02,
        seed: 23,
    }));
    let pool = Arc::new(ComputePool::start("artifacts", 1).unwrap());
    let res = run_sgd(
        &system,
        data.clone(),
        SgdConfig {
            iters: 30,
            batch: 128, // must match the artifact's B
            policy: PolicyConfig::Vap { v_thr: 4.0, strong: false },
            eta: Some(0.25),
            use_xla: true,
            ..SgdConfig::default()
        },
        Some(pool),
    )
    .unwrap();
    assert!(res.accuracy > 0.8, "accuracy {}", res.accuracy);
    system.shutdown().unwrap();
}

/// End-to-end transformer smoke: a few data-parallel steps through the
/// full stack; loss must be finite and ≈ ln(V) at init.
#[test]
fn transformer_smoke_three_steps() {
    require_artifacts!();
    let spec = Arc::new(TransformerSpec::load("artifacts").unwrap());
    let system = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(1)
            .threads_per_proc(2)
            .flush_interval_us(100)
            .wait_timeout_ms(120_000)
            .build(),
    )
    .unwrap();
    let pool = Arc::new(ComputePool::start("artifacts", 1).unwrap());
    let res = train(
        &system,
        spec.clone(),
        pool,
        TrainConfig {
            steps: 3,
            eta: 0.1,
            policy: PolicyConfig::Ssp { staleness: 1 },
            seed: 42,
            log_every: 0,
        },
    )
    .unwrap();
    assert_eq!(res.loss_curve.len(), 3);
    let first = res.loss_curve[0];
    let uniform = (spec.vocab as f64).ln();
    assert!(first.is_finite());
    assert!(
        (first - uniform).abs() < 1.0,
        "initial loss {first} should be near ln(V) = {uniform}"
    );
    system.shutdown().unwrap();
}

/// Artifact input-shape mismatches surface as errors, not wrong numbers.
#[test]
fn pjrt_shape_mismatch_is_an_error() {
    require_artifacts!();
    let pool = ComputePool::start("artifacts", 1).unwrap();
    let r = pool.run(
        "logreg_grad",
        vec![
            Tensor::zeros(vec![32]), // artifact expects D=64
            Tensor::zeros(vec![128, 32]),
            Tensor::zeros(vec![128]),
        ],
    );
    assert!(r.is_err(), "mismatched shapes must fail loudly");
    pool.shutdown();
}
