//! The parallel apply path must be invisible in the state: a shard
//! configured with `apply_threads > 1` fans each push batch's row updates
//! across lane-partitioned workers, but per-row apply order is the batch
//! slice order either way — so the resulting float state is required to be
//! *byte-identical* to the sequential shard's, and the deterministic
//! simulator is required to produce byte-identical runs per seed whatever
//! the thread count.

use std::sync::Arc;

use bapps::comm::msg::{Msg, Payload, PushBatch};
use bapps::comm::Network;
use bapps::config::{NetConfig, PolicyConfig};
use bapps::server::{MemPersistence, ServerShard, ShardOptions, TableRegistry};
use bapps::sim::{Sim, SimConfig};
use bapps::table::{RowId, RowKind, RowUpdate, TableDesc, TableId};
use bapps::trace::TraceRecorder;
use bapps::types::{NodeId, ProcId, ShardId};
use bapps::util::Rng64;

const TABLE: TableId = TableId(0);
const ROWS: u64 = 97; // prime: rows collide across stripes and lanes
const WIDTH: u32 = 8;
const PROCS: u32 = 2;
const BATCHES: u64 = 60;
const UPDATES_PER_BATCH: usize = 64;

/// Deterministic mixed dense/sparse push workload, two origins.
fn workload(seed: u64) -> Vec<PushBatch> {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut next_id = [0u64; PROCS as usize];
    (0..BATCHES)
        .map(|_| {
            let origin = rng.below(PROCS as usize);
            let updates: Vec<(RowId, RowUpdate)> = (0..UPDATES_PER_BATCH)
                .map(|_| {
                    let row = RowId(rng.below(ROWS as usize) as u64);
                    let u = if rng.chance(0.5) {
                        RowUpdate::Dense(
                            (0..WIDTH).map(|_| (rng.f32() * 2.0 - 1.0) * 3.0).collect(),
                        )
                    } else {
                        RowUpdate::single(rng.below(WIDTH as usize) as u32, rng.f32() - 0.5)
                    };
                    (row, u)
                })
                .collect();
            let batch_id = next_id[origin];
            next_id[origin] += 1;
            PushBatch {
                table: TABLE,
                origin: ProcId(origin as u32),
                batch_id,
                updates: Arc::new(updates),
                clock: 1,
                epoch: 0,
                trace: bapps::trace::TraceCtx::NONE,
            }
        })
        .collect()
}

/// Run `batches` through a fresh shard and return the exact bit pattern of
/// every row in both the authoritative and forwarded-prefix stores.
fn shard_state_bits(apply_threads: u32, batches: &[PushBatch]) -> Vec<(u64, Vec<u32>)> {
    let net = Network::new(NetConfig::default());
    let registry = Arc::new(TableRegistry::default());
    registry
        .insert(TableDesc {
            id: TABLE,
            num_rows: ROWS,
            row_width: WIDTH,
            row_kind: RowKind::Dense,
            policy: PolicyConfig::BestEffort,
        })
        .unwrap();
    let _shard_ep = net.register(NodeId::Server(ShardId(0)));
    let _clients: Vec<_> = (0..PROCS).map(|p| net.register(NodeId::Client(ProcId(p)))).collect();
    let mut opts = ShardOptions::new(Arc::new(MemPersistence::new()));
    opts.apply_threads = apply_threads;
    let mut shard = ServerShard::with_options(
        ShardId(0),
        PROCS,
        registry,
        net.sender(),
        Arc::new(TraceRecorder::new(false)),
        opts,
    );
    for b in batches {
        shard.handle(Msg {
            src: NodeId::Client(b.origin),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::PushUpdates(b.clone()),
        });
    }
    let cp = shard.export_checkpoint();
    let mut bits = Vec::new();
    for t in &cp.tables {
        for (tag, image) in [(0u64, &t.store), (1u64, &t.fwd)] {
            for (row, data, clock) in image {
                let key = (tag << 32) | (u64::from(t.id.0) << 40) | row.0;
                let mut cols: Vec<u32> =
                    data.to_dense(WIDTH).iter().map(|v| v.to_bits()).collect();
                cols.push(*clock);
                bits.push((key, cols));
            }
        }
    }
    bits
}

/// Stripe-parallel applies must leave state byte-identical to sequential:
/// every row of both stores, compared at the `f32` bit level, across lane
/// counts that divide the stripes evenly and unevenly.
#[test]
fn pooled_shard_state_is_byte_identical_to_sequential() {
    for seed in [11u64, 23, 47] {
        let batches = workload(seed);
        let baseline = shard_state_bits(1, &batches);
        assert!(!baseline.is_empty(), "workload must touch rows");
        for threads in [2u32, 3, 4, 8] {
            let got = shard_state_bits(threads, &batches);
            assert_eq!(got, baseline, "seed {seed}, apply_threads {threads}");
        }
    }
}

/// The deterministic simulator must be a pure function of `(config, seed)`
/// even with the apply pool engaged: same trace fingerprint, same rendered
/// metrics snapshot, no oracle violations.
#[test]
fn sim_runs_are_byte_identical_across_apply_threads() {
    for (seed, policy) in [
        (9301u64, PolicyConfig::Ssp { staleness: 1 }),
        (9302, PolicyConfig::Vap { v_thr: 2.0, strong: true }),
        (9303, PolicyConfig::BestEffort),
    ] {
        let base = SimConfig::default().with_policy(policy).with_seed(seed);
        let r1 = Sim::run(&base);
        assert!(r1.violations.is_empty(), "seed {seed}: {:?}", r1.violations);
        for threads in [2u32, 4] {
            let mut cfg = base.clone();
            cfg.apply_threads = threads;
            let r = Sim::run(&cfg);
            assert!(r.violations.is_empty(), "seed {seed} t{threads}: {:?}", r.violations);
            assert_eq!(r.trace_hash, r1.trace_hash, "seed {seed} t{threads}: trace diverged");
            assert_eq!(
                r.snapshot.render_json(),
                r1.snapshot.render_json(),
                "seed {seed} t{threads}: metrics snapshot diverged"
            );
        }
    }
}
