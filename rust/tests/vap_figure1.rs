//! Figure 1 reproduction: the VAP blocking schedule.
//!
//! The paper's figure: `v_thr = 8`; a worker applies updates
//! `(1,1) (2,3) (3,2) (4,1) (5,1)` — accumulated unsynchronized sum 8 —
//! then update `(6,2)` must BLOCK, and may proceed only after the system
//! has made enough earlier updates visible to all workers.
//!
//! We reproduce it end-to-end on a live system with the trace recorder:
//! a writer worker replays the figure's update stream against a VAP table
//! while a slow network delays visibility; the trace must show a
//! `BlockStart(ValueBound)` before the 6th update's `Inc` and a
//! `BlockEnd` after at least one `Visible` event.

use bapps::config::{NetConfig, PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::table::{RowId, RowKind, TableDesc, TableId};
use bapps::trace::{BlockReason, Event};

fn fig1_system(latency_us: u64) -> PsSystem {
    PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(1)
            .num_client_procs(2) // a second process must ack for visibility
            .threads_per_proc(1)
            .net(NetConfig { latency_us, bandwidth_bps: 0, jitter_us: 0, seed: 1 })
            .flush_interval_us(50)
            .trace(true)
            .wait_timeout_ms(30_000)
            .build(),
    )
    .unwrap()
}

fn vap_table() -> TableDesc {
    TableDesc {
        id: TableId(0),
        num_rows: 4,
        row_width: 4,
        row_kind: RowKind::Dense,
        policy: PolicyConfig::Vap { v_thr: 8.0, strong: false },
    }
}

#[test]
fn figure1_schedule_blocks_sixth_update_and_recovers() {
    // 5 ms link latency: visibility acks take ≥ 4 hops, so the writer
    // observably blocks at the bound.
    let sys = fig1_system(5_000);
    sys.create_table(vap_table()).unwrap();

    let deltas = [1.0f32, 3.0, 2.0, 1.0, 1.0, 2.0]; // Fig 1's update values
    sys.run_workers(move |ctx| {
        if ctx.worker_id().0 != 0 {
            return; // worker 1 only acks (its ingress thread does the work)
        }
        let t = ctx.table(TableId(0));
        for d in deltas.iter() {
            t.inc(RowId(0), 0, *d).unwrap();
        }
    })
    .unwrap();

    let events = sys.trace().events();
    let render = sys.trace().render();

    // Find the 6th Inc on (row 0, col 0) and the ValueBound block events.
    let mut incs = 0usize;
    let mut block_start_idx = None;
    let mut block_end_idx = None;
    let mut sixth_inc_idx = None;
    let mut first_visible_idx = None;
    for (i, e) in events.iter().enumerate() {
        match e {
            Event::Inc { row, col, .. } if row.0 == 0 && *col == 0 => {
                incs += 1;
                if incs == 6 {
                    sixth_inc_idx = Some(i);
                }
            }
            Event::BlockStart { reason: BlockReason::ValueBound, .. } => {
                block_start_idx.get_or_insert(i);
            }
            Event::BlockEnd { reason: BlockReason::ValueBound, .. } => {
                block_end_idx.get_or_insert(i);
            }
            Event::Visible { .. } => {
                first_visible_idx.get_or_insert(i);
            }
            _ => {}
        }
    }

    assert_eq!(incs, 6, "all six updates must eventually apply:\n{render}");
    let bs = block_start_idx.expect("the 6th update must hit the value gate");
    let be = block_end_idx.expect("the blocked writer must resume");
    let vis = first_visible_idx.expect("visibility acks must flow");
    assert!(vis < be, "unblocking requires a visibility event first:\n{render}");
    assert!(bs < be, "block must start before it ends:\n{render}");

    sys.shutdown().unwrap();
}

#[test]
fn first_five_updates_do_not_block() {
    // Same stream minus the 6th update: no ValueBound block may occur
    // (the accumulated sum reaches exactly v_thr but never exceeds it).
    let sys = fig1_system(2_000);
    sys.create_table(vap_table()).unwrap();
    sys.run_workers(move |ctx| {
        if ctx.worker_id().0 != 0 {
            return;
        }
        let t = ctx.table(TableId(0));
        for d in [1.0f32, 3.0, 2.0, 1.0, 1.0] {
            t.inc(RowId(0), 0, d).unwrap();
        }
    })
    .unwrap();
    let blocked = sys
        .trace()
        .events()
        .iter()
        .any(|e| matches!(e, Event::BlockStart { reason: BlockReason::ValueBound, .. }));
    assert!(!blocked, "sum ≤ v_thr must not block:\n{}", sys.trace().render());
    sys.shutdown().unwrap();
}

#[test]
fn visibility_eventually_drains_all_mass() {
    // After the run, all batches must have become visible (no stuck
    // holds): write a long alternating stream and assert every Push has a
    // matching Visible in the trace.
    let sys = fig1_system(500);
    sys.create_table(vap_table()).unwrap();
    sys.run_workers(move |ctx| {
        if ctx.worker_id().0 != 0 {
            return;
        }
        let t = ctx.table(TableId(0));
        for i in 0..200 {
            // churn with net drift: cancellation exercises the signed
            // accounting, the +1 net mass per 3 updates keeps batches
            // shipping (fully-cancelled aggregates are correctly dropped)
            let d = if i % 3 == 2 { -1.0 } else { 1.0 };
            t.inc(RowId(0), 0, d).unwrap();
        }
        // let the pipeline drain before shutdown
        std::thread::sleep(std::time::Duration::from_millis(300));
    })
    .unwrap();
    let events = sys.trace().events();
    let pushes = events.iter().filter(|e| matches!(e, Event::Push { .. })).count();
    let visibles = events.iter().filter(|e| matches!(e, Event::Visible { .. })).count();
    assert!(pushes > 0, "stream must actually ship");
    assert!(
        visibles >= pushes.saturating_sub(2),
        "almost all pushes must become visible: pushes={pushes} visibles={visibles}"
    );
    sys.shutdown().unwrap();
}
