//! The causal tracer's determinism contract under the sim: spans are
//! timestamped from the virtual clock, rings register in construction
//! order and export with a fixed sort, so the Perfetto `trace.json` is a
//! *byte-identical* function of `(SimConfig, seed)` — and the span-tree
//! oracle (run inside every sim) guarantees that each accepted batch has
//! a closed batch→net→apply→visible chain with no orphan spans, across
//! all six consistency policies.

use bapps::config::PolicyConfig;
use bapps::metrics::{SampleValue, Snapshot};
use bapps::sim::{Sim, SimConfig};

/// Sample count of one `trace_stage_us` label set (0 when unregistered).
fn stage_count(snap: &Snapshot, stage: &str) -> u64 {
    match snap.sample("trace_stage_us", &[("stage", stage)]).map(|s| &s.value) {
        Some(SampleValue::Histogram { count, .. }) => *count,
        _ => 0,
    }
}

fn policies() -> [PolicyConfig; 6] {
    [
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 1 },
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
    ]
}

/// Two runs of the same seed/config must export the same bytes — the
/// whole file, not a fingerprint, so any nondeterministic timestamp or
/// ordering wobble fails loudly.
#[test]
fn trace_json_byte_identical_across_same_seed_runs() {
    for pol in policies() {
        let cfg = SimConfig::default().with_policy(pol).with_seed(4242);
        let a = Sim::run_traced(&cfg);
        let b = Sim::run_traced(&cfg);
        assert!(a.ok(), "policy {:?}:\n{}", pol, a.describe());
        let ja = a.trace_json.expect("run_traced populates trace_json");
        let jb = b.trace_json.expect("run_traced populates trace_json");
        assert!(!ja.is_empty());
        assert_eq!(ja, jb, "{:?}: trace.json differs across identical runs", pol);
        // Sanity: the export is a real trace, not an empty envelope.
        assert!(ja.starts_with("{\"traceEvents\":["), "{:?}: bad envelope", pol);
        assert!(ja.contains("\"ph\":\"X\""), "{:?}: no spans exported", pol);
    }
}

/// Different seeds must *not* collapse to the same trace (guards against
/// the export accidentally ignoring the schedule).
#[test]
fn trace_json_varies_with_seed() {
    let a = Sim::run_traced(&SimConfig::default().with_seed(4242));
    let b = Sim::run_traced(&SimConfig::default().with_seed(4243));
    assert_ne!(a.trace_json, b.trace_json, "distinct seeds exported identical traces");
}

/// Span-chain completeness across every policy: the oracle inside the
/// sim cross-checks each accepted `(origin, batch_id)` against the span
/// rings and records a violation for any missing stage or orphan span —
/// `r.ok()` is the assertion. Several seeds per policy so strong-VAP
/// holds and partial drains are exercised, plus the stage histograms
/// must agree with the ring contents.
#[test]
fn span_chains_complete_for_every_applied_batch() {
    for pol in policies() {
        for seed in [7000u64, 7001, 7002] {
            let cfg = SimConfig::default().with_policy(pol).with_seed(seed);
            let r = Sim::run_traced(&cfg);
            assert!(r.ok(), "policy {:?} seed {seed}:\n{}", pol, r.describe());
            assert!(r.oracle_applied_batches > 0, "{:?} seed {seed}: no batches applied", pol);
            // Every accepted batch closed a net and an apply span, and
            // the registry histograms were fed one sample per span.
            assert_eq!(
                stage_count(&r.snapshot, "net"),
                r.oracle_applied_batches,
                "{:?} seed {seed}: net span count != accepted batches",
                pol
            );
            assert_eq!(
                stage_count(&r.snapshot, "apply"),
                r.oracle_applied_batches,
                "{:?} seed {seed}: apply span count != accepted batches",
                pol
            );
            assert_eq!(
                r.snapshot.counter_sum("trace_spans_dropped_total"),
                0,
                "{:?} seed {seed}: ring overflow at default capacity",
                pol
            );
        }
    }
}
