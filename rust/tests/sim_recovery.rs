//! Crash-recovery acceptance suite.
//!
//! Exercises the shard crash → heartbeat detection → checkpoint + WAL
//! respawn → client resync path through the deterministic sim harness: a
//! ≥200-run seeded sweep across all six policies with a mid-run shard
//! crash must uphold every bound; crash runs must stay byte-identical
//! per seed; a recovery that skips WAL replay must be caught by the
//! oracles; and the shrinker must keep the crash exactly when the
//! failure needs it.

use bapps::config::PolicyConfig;
use bapps::sim::{shrink, sweep, Sabotage, Sim, SimConfig};

fn policies() -> Vec<PolicyConfig> {
    vec![
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 1 },
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
    ]
}

/// The headline acceptance sweep: 6 policies × 3 crash schedules × 12
/// seeds = 216 runs, each killing a shard mid-run (in-memory state and
/// in-flight messages destroyed) and recovering it from checkpoint +
/// WAL, every run checked by every oracle.
#[test]
fn crash_recovery_sweep_upholds_all_bounds() {
    let mut runs = 0;
    for pol in policies() {
        for (shard, at_us) in [(0u32, 1_500u64), (1, 4_000), (0, 8_000)] {
            let base = SimConfig::default().with_policy(pol).with_crash(shard, at_us, 2_000);
            let out = sweep(&base, 500..512);
            assert!(out.ok(), "policy {:?} crash@{at_us}:\n{}", pol, out.describe());
            runs += out.runs;
        }
    }
    assert!(runs >= 200, "crash sweep too small: {runs} runs");
}

/// Identical seed + config ⇒ byte-identical trace, crash included (the
/// crash, detection, restart and resync are all virtual-time events).
#[test]
fn crash_trace_identity() {
    for pol in policies() {
        let cfg = SimConfig::default().with_policy(pol).with_seed(9).with_crash(1, 2_000, 2_500);
        let a = Sim::run(&cfg);
        let b = Sim::run(&cfg);
        assert_eq!(a.crashes, 1, "{:?}: crash never fired", pol);
        assert_eq!(
            (a.trace_hash, a.trace_lines),
            (b.trace_hash, b.trace_lines),
            "{:?}: nondeterministic crash trace",
            pol
        );
        assert!(a.ok(), "policy {:?}:\n{}", pol, a.describe());
    }
}

/// A recovery that restores the checkpoint but skips WAL replay silently
/// loses every push applied since the last checkpoint — the oracles
/// (quiescence / read-my-writes) must catch it. This is the harness's
/// proof that the crash sweep actually depends on replay being correct.
#[test]
fn skipped_wal_replay_is_caught() {
    let mut caught = false;
    for seed in 1..=10u64 {
        let mut cfg = SimConfig::default()
            .with_policy(PolicyConfig::Ssp { staleness: 1 })
            .with_seed(seed)
            .with_crash(0, 1_000, 1_500);
        cfg.sabotage = Sabotage::SkipWalReplay;
        let r = Sim::run(&cfg);
        if !r.ok() {
            caught = true;
            break;
        }
    }
    assert!(caught, "no oracle fired on a recovery that skipped WAL replay");
}

/// The virtual-time flusher hook (sim analogue of the production flusher
/// threads) drives CAP/VAP eager propagation between clock boundaries —
/// with and without a crash — without violating any bound, and stays
/// deterministic.
#[test]
fn virtual_flusher_exercises_eager_propagation() {
    let pols = [
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
    ];
    for pol in pols {
        let mut cfg = SimConfig::default().with_policy(pol).with_seed(33);
        cfg.flusher_every_us = 150;
        let a = Sim::run(&cfg);
        assert!(a.ok(), "policy {:?} (flusher on):\n{}", pol, a.describe());
        let b = Sim::run(&cfg);
        assert_eq!(a.trace_hash, b.trace_hash, "{:?}: nondeterministic flusher", pol);

        let crashed = Sim::run(&cfg.clone().with_crash(0, 2_000, 2_000));
        assert!(crashed.ok(), "policy {:?} (flusher + crash):\n{}", pol, crashed.describe());
    }
}

/// Shrinking a failure that does not need the crash must drop it first:
/// the sabotaged write gate fails under any schedule, so the minimal
/// reproduction is crash-free.
#[test]
fn shrink_removes_crash_when_not_load_bearing() {
    let mut cfg = SimConfig::default()
        .with_policy(PolicyConfig::Vap { v_thr: 1.0, strong: false })
        .with_seed(4)
        .with_crash(0, 2_000, 2_000);
    cfg.sabotage = Sabotage::WriteGate;
    let (min_cfg, rep) = shrink(&cfg);
    assert!(!rep.ok(), "shrunk config must still fail");
    assert!(min_cfg.faults.crash.is_none(), "crash should be shrunk away");
}

/// Shrinking a failure that exists only because of the crash (lost WAL
/// tail) must keep the crash: removing it makes the run pass, so the
/// shrinker rejects that candidate.
#[test]
fn shrink_keeps_crash_when_it_is_load_bearing() {
    let mut failing = None;
    for seed in 1..=10u64 {
        let mut cfg = SimConfig::default()
            .with_policy(PolicyConfig::Ssp { staleness: 1 })
            .with_seed(seed)
            .with_crash(0, 1_000, 1_500);
        cfg.sabotage = Sabotage::SkipWalReplay;
        if !Sim::run(&cfg).ok() {
            failing = Some(cfg);
            break;
        }
    }
    let cfg = failing.expect("no failing seed for the WAL-replay sabotage");
    let (min_cfg, rep) = shrink(&cfg);
    assert!(!rep.ok(), "shrunk config must still fail");
    assert!(min_cfg.faults.crash.is_some(), "the crash is load-bearing and must survive shrinking");
}
