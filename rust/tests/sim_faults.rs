//! Deterministic-simulation acceptance suite.
//!
//! Exercises the real client/server/consistency stack through the `sim`
//! harness: a ≥1000-seed sweep across all six policies under chaos faults
//! must uphold every bound; identical seeds must produce byte-identical
//! traces; sabotaged gates must be caught; the shrinker must minimize a
//! failing schedule.

use bapps::config::PolicyConfig;
use bapps::sim::{shrink, sweep, FaultConfig, Sabotage, Sim, SimConfig};

fn policies() -> Vec<PolicyConfig> {
    vec![
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 1 },
        PolicyConfig::Cap { staleness: 1 },
        PolicyConfig::Vap { v_thr: 2.0, strong: false },
        PolicyConfig::Vap { v_thr: 2.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
    ]
}

/// The headline acceptance sweep: 6 policies × 170 seeds = 1020 runs
/// under the chaos fault mix (latency, jitter, drops-with-retry,
/// duplicates), every run checked by every oracle.
#[test]
fn thousand_seed_chaos_sweep_upholds_all_bounds() {
    for pol in policies() {
        let base = SimConfig::default().with_policy(pol);
        let out = sweep(&base, 1000..1170);
        assert!(out.ok(), "policy {:?}:\n{}", pol, out.describe());
        assert_eq!(out.runs, 170);
    }
}

/// Identical seed + config ⇒ byte-identical event trace, for every
/// policy, fault mix on.
#[test]
fn trace_identity_per_policy() {
    for pol in policies() {
        for seed in [42, 43] {
            let cfg = SimConfig::default().with_policy(pol).with_seed(seed);
            let a = Sim::run(&cfg);
            let b = Sim::run(&cfg);
            assert_eq!(
                (a.trace_hash, a.trace_lines),
                (b.trace_hash, b.trace_lines),
                "{:?} seed {seed}: nondeterministic trace",
                pol
            );
        }
    }
}

/// Stragglers (one worker 8× slower, one 3×) stress the staleness gates
/// without violating them.
#[test]
fn straggler_sweep_is_clean() {
    for pol in policies() {
        let mut base = SimConfig::default().with_policy(pol);
        base.stragglers = vec![(0, 8.0), (3, 3.0)];
        let out = sweep(&base, 300..316);
        assert!(out.ok(), "policy {:?}:\n{}", pol, out.describe());
    }
}

/// A deliberately broken read gate (reads claim clock 0) must be caught
/// by the staleness oracle — the harness's own self-test, driven through
/// the public API.
#[test]
fn broken_read_gate_is_caught() {
    let mut caught = false;
    for seed in 1..=8u64 {
        let mut cfg = SimConfig::default().with_policy(PolicyConfig::Bsp).with_seed(seed);
        cfg.sabotage = Sabotage::ReadGate;
        cfg.faults = FaultConfig { latency_us: 500, jitter_us: 200, ..FaultConfig::none() };
        cfg.op_cost_us = 10;
        let r = Sim::run(&cfg);
        if r.violations.iter().any(|v| v.kind == "staleness") {
            caught = true;
            break;
        }
    }
    assert!(caught, "staleness oracle never fired on a sabotaged read gate");
}

/// A deliberately broken write gate must be caught by the value-bound
/// oracle, and the shrinker must reduce the failure to a fault-free,
/// small-workload reproduction.
#[test]
fn broken_write_gate_is_caught_and_shrunk() {
    let mut cfg = SimConfig::default()
        .with_policy(PolicyConfig::Vap { v_thr: 1.0, strong: false })
        .with_seed(7);
    cfg.sabotage = Sabotage::WriteGate;
    let r = Sim::run(&cfg);
    assert!(
        r.violations.iter().any(|v| v.kind == "value-bound"),
        "value oracle never fired: {}",
        r.describe()
    );

    let (min_cfg, min_rep) = shrink(&cfg);
    assert!(!min_rep.ok(), "shrunk reproduction must still fail");
    assert_eq!(min_cfg.faults.dup_p, 0.0);
    assert_eq!(min_cfg.faults.drop_p, 0.0);
    assert_eq!(min_cfg.faults.jitter_us, 0);
    assert!(min_cfg.rounds < cfg.rounds);
    assert!(!min_rep.trace_tail.is_empty(), "minimal repro carries its schedule tail");
}

/// Fault bookkeeping sanity: the chaos mix actually injects what it
/// claims (retransmissions and duplicates occur, duplicates are filtered,
/// delivery is exactly-once).
#[test]
fn chaos_faults_actually_fire() {
    let r = Sim::run(&SimConfig::default().with_seed(77));
    assert!(r.ok(), "{}", r.describe());
    assert!(r.net.delayed_retrans > 0, "no retransmissions at drop_p = 0.05");
    assert!(r.net.duplicates_injected > 0, "no duplicates at dup_p = 0.05");
    assert_eq!(
        r.net.duplicates_injected, r.net.duplicates_filtered,
        "every injected duplicate must be filtered at the receiver edge"
    );
    assert_eq!(
        r.net.sent, r.net.delivered,
        "exactly-once delivery: every sent message delivered exactly once"
    );
}
