//! End-to-end verification of the paper's consistency guarantees on live
//! systems: the SSP/CAP staleness bound, the weak/strong VAP divergence
//! bounds (§2.2), read-my-writes and FIFO (§2), and the BSP Lemma (§3).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use bapps::config::{NetConfig, PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::table::{RowId, RowKind, TableDesc, TableId};

fn sys(shards: u32, procs: u32, threads: u32, net: NetConfig) -> PsSystem {
    PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(shards)
            .num_client_procs(procs)
            .threads_per_proc(threads)
            .net(net)
            .flush_interval_us(50)
            .wait_timeout_ms(20_000)
            .build(),
    )
    .unwrap()
}

fn table(id: u32, policy: PolicyConfig) -> TableDesc {
    TableDesc { id: TableId(id), num_rows: 32, row_width: 4, row_kind: RowKind::Dense, policy }
}

/// The clock-bounded guarantee: a reader at clock c sees ALL updates
/// stamped ≤ c−s−1 from every worker. Each worker writes exactly one +1
/// per clock to a shared cell; after `clock()` to c, a read must be
/// ≥ P·(c−s−1) (every worker's first c−s−1 increments).
#[test]
fn ssp_staleness_bound_holds() {
    for (policy, s) in [
        (PolicyConfig::Ssp { staleness: 1 }, 1u32),
        (PolicyConfig::Cap { staleness: 2 }, 2u32),
        (PolicyConfig::Bsp, 0u32),
    ] {
        let system = sys(2, 2, 2, NetConfig::default());
        system.create_table(table(0, policy)).unwrap();
        let p = system.config().num_workers();
        let violations = Arc::new(AtomicU32::new(0));
        let v = violations.clone();
        system
            .run_workers(move |ctx| {
                let t = ctx.table(TableId(0));
                for _ in 0..12u32 {
                    t.inc(RowId(0), 0, 1.0).unwrap();
                    let c = ctx.clock().unwrap();
                    let seen = t.get(RowId(0), 0).unwrap();
                    let required = (c.saturating_sub(s + 1)) as f32 * p as f32;
                    if seen + 0.001 < required {
                        v.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "VIOLATION [{}]: clock {c} saw {seen} < required {required}",
                            policy.name()
                        );
                    }
                }
            })
            .unwrap();
        assert_eq!(
            violations.load(Ordering::Relaxed),
            0,
            "staleness violated under {}",
            policy.name()
        );
        system.shutdown().unwrap();
    }
}

/// Read-my-writes (paper §2): a worker always sees its own updates, sent
/// or not, under EVERY policy.
#[test]
fn read_my_writes_under_all_policies() {
    for policy in [
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 2 },
        PolicyConfig::Cap { staleness: 2 },
        PolicyConfig::Vap { v_thr: 1e6, strong: false },
        PolicyConfig::Vap { v_thr: 1e6, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 1e6, strong: false },
        PolicyConfig::BestEffort,
    ] {
        let system = sys(2, 2, 1, NetConfig { latency_us: 300, ..NetConfig::default() });
        system.create_table(table(0, policy)).unwrap();
        system
            .run_workers(move |ctx| {
                let t = ctx.table(TableId(0));
                let my_row = RowId(ctx.worker_id().0 as u64);
                let mut mine = 0.0f32;
                for i in 0..50 {
                    t.inc(my_row, 0, 1.0).unwrap();
                    mine += 1.0;
                    let seen = t.get(my_row, 0).unwrap();
                    assert!(
                        seen >= mine - 0.001,
                        "[{}] lost own writes at step {i}: saw {seen} < {mine}",
                        policy.name()
                    );
                    if i % 10 == 0 {
                        ctx.clock().unwrap();
                    }
                }
            })
            .unwrap();
        system.shutdown().unwrap();
    }
}

/// FIFO consistency (paper §2): worker A's updates become visible to B in
/// issue order. A writes a *monotone counter* twice per step (col 0 then
/// col 1, col1 ≤ col0 always at the writer); any reader must never
/// observe col1 > col0 — that would require seeing a later update before
/// an earlier one.
#[test]
fn fifo_update_visibility() {
    let system = sys(1, 2, 1, NetConfig { latency_us: 200, jitter_us: 400, ..NetConfig::default() });
    system.create_table(table(0, PolicyConfig::BestEffort)).unwrap();
    system
        .run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            if ctx.worker_id().0 == 0 {
                // writer: col0 += 1 then col1 += 1, so col0 ≥ col1 in
                // every prefix of the update stream
                for _ in 0..300 {
                    t.inc(RowId(0), 0, 1.0).unwrap();
                    t.inc(RowId(0), 1, 1.0).unwrap();
                }
            } else {
                // reader: col1 ≤ col0 must hold in every observed state
                for _ in 0..300 {
                    // read col1 FIRST: any reordering error is made worse
                    // by reading col0 later, so this direction is safe
                    let c1 = t.get(RowId(0), 1).unwrap();
                    let c0 = t.get(RowId(0), 0).unwrap();
                    assert!(
                        c0 >= c1 - 0.001,
                        "FIFO violated: col0={c0} < col1={c1}"
                    );
                    std::thread::yield_now();
                }
            }
        })
        .unwrap();
    system.shutdown().unwrap();
}

/// Weak-VAP divergence bound (§2.2): |θ_A − θ_B| ≤ max(u, v_thr)·P.
/// Workers hammer one cell with +1s under a slow network while
/// continuously reading it; every observed divergence between the shared
/// true total and any worker's view stays within the bound.
#[test]
fn weak_vap_divergence_bound() {
    let v_thr = 4.0f32;
    let u = 1.0f32;
    let system = sys(1, 2, 2, NetConfig { latency_us: 500, ..NetConfig::default() });
    system
        .create_table(table(0, PolicyConfig::Vap { v_thr, strong: false }))
        .unwrap();
    let p = system.config().num_workers();
    let bound = v_thr.max(u) * p as f32 + 0.001;

    // The "true" total is tracked with a shared atomic the workers bump
    // exactly when they Inc.
    let truth = Arc::new(AtomicU32::new(0));
    let tviews = truth.clone();
    let max_div = Arc::new(std::sync::Mutex::new(0.0f32));
    let mdiv = max_div.clone();
    system
        .run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            for _ in 0..150 {
                t.inc(RowId(0), 0, 1.0).unwrap();
                tviews.fetch_add(1, Ordering::SeqCst);
                // Sample the truth BEFORE the view: the view can only
                // grow in between, so `truth_pre − seen` under-estimates
                // the instantaneous divergence — a failure here is a real
                // bound violation, never a sampling artifact.
                let truth_pre = tviews.load(Ordering::SeqCst) as f32;
                let seen = t.get(RowId(0), 0).unwrap();
                let div = (truth_pre - seen).max(0.0);
                let mut m = mdiv.lock().unwrap();
                if div > *m {
                    *m = div;
                }
            }
        })
        .unwrap();
    let observed = *max_div.lock().unwrap();
    // The bound compares *replica states*; our truth-sampling can add up
    // to P in-flight increments of skew, so allow that margin.
    assert!(
        observed <= bound + p as f32,
        "weak VAP divergence {observed} exceeded bound {bound} (+P margin)"
    );
    system.shutdown().unwrap();
}

/// The BSP Lemma (§3): zero-staleness clock-bounded execution reduces to
/// BSP — after clocking to c, a reader sees the full effect of all
/// workers' first c−1 clocks. (The paper's eq. (1) additionally allows
/// best-effort *extra* in-window updates, which our server-push
/// implementation delivers eagerly, so the upper side of the window is
/// bounded by the permitted clock lead: a peer may run at most s+2 = 2
/// clocks past the reader before its own read gate stops it.)
#[test]
fn bsp_lemma_zero_staleness_is_bsp() {
    let system = sys(2, 2, 2, NetConfig::default());
    system.create_table(table(0, PolicyConfig::Ssp { staleness: 0 })).unwrap();
    let p = system.config().num_workers();
    system
        .run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            for step in 1..=8u32 {
                t.inc(RowId(0), 0, 1.0).unwrap();
                ctx.clock().unwrap();
                let seen = t.get(RowId(0), 0).unwrap();
                // guaranteed floor: every worker's first step-1 updates
                let lo = (p * (step - 1)) as f32 - 0.001;
                // ceiling: no peer can be more than 2 clocks ahead of the
                // slowest worker (tick, then its next read blocks), and
                // the reader is at `step`, so ≤ P·(step+2).
                let hi = (p * (step + 2)) as f32 + 0.001;
                assert!(
                    seen >= lo && seen <= hi,
                    "BSP window violated at step {step}: {seen} ∉ [{lo},{hi}]"
                );
            }
        })
        .unwrap();
    system.shutdown().unwrap();
}

/// Different tables may run different models concurrently (paper §4.1).
#[test]
fn mixed_policies_coexist() {
    let system = sys(2, 2, 2, NetConfig::default());
    system.create_table(table(0, PolicyConfig::Bsp)).unwrap();
    system.create_table(table(1, PolicyConfig::Vap { v_thr: 2.0, strong: false })).unwrap();
    system.create_table(table(2, PolicyConfig::BestEffort)).unwrap();
    system
        .run_workers(move |ctx| {
            let a = ctx.table(TableId(0));
            let b = ctx.table(TableId(1));
            let c = ctx.table(TableId(2));
            for i in 0..20u64 {
                a.inc(RowId(i % 32), 0, 1.0).unwrap();
                b.inc(RowId(i % 32), 1, 0.5).unwrap();
                c.inc(RowId(i % 32), 2, -0.5).unwrap();
                ctx.clock().unwrap();
            }
        })
        .unwrap();
    system.shutdown().unwrap();
}

/// Paper §2.1's algorithmic argument for CAP over SSP: with eager
/// propagation "clients are more likely to compute with fresh data".
/// Measured as the observed read-staleness distribution: under CAP the
/// mass concentrates at low staleness even with the same bound s, because
/// updates ship continuously instead of at the clock boundary.
#[test]
fn cap_reads_fresher_than_ssp_at_equal_bound() {
    let mean_staleness = |policy: PolicyConfig| -> f64 {
        let system = sys(2, 2, 2, NetConfig::default());
        system.create_table(table(0, policy)).unwrap();
        system
            .run_workers(move |ctx| {
                let t = ctx.table(TableId(0));
                for i in 0..200u64 {
                    t.inc(RowId(i % 32), 0, 1.0).unwrap();
                    let _ = t.get(RowId((i + 7) % 32), 0).unwrap();
                    if i % 4 == 3 {
                        // uneven clocking creates real skew for the gate
                        std::thread::sleep(std::time::Duration::from_micros(
                            50 * (ctx.worker_id().0 as u64 + 1),
                        ));
                        ctx.clock().unwrap();
                    }
                }
            })
            .unwrap();
        // Weighted mean over the power-of-two staleness histogram.
        let mut num = 0.0;
        let mut den = 0.0;
        for core in system.clients() {
            for (i, &c) in core.staleness.snapshot().iter().enumerate() {
                let bucket_mid = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 * 1.5 };
                num += bucket_mid * c as f64;
                den += c as f64;
            }
        }
        system.shutdown().unwrap();
        num / den.max(1.0)
    };

    let ssp = mean_staleness(PolicyConfig::Ssp { staleness: 4 });
    let cap = mean_staleness(PolicyConfig::Cap { staleness: 4 });
    // CAP must not read staler than SSP on average; typically it is
    // strictly fresher. Allow equality within 20% noise.
    assert!(
        cap <= ssp * 1.2 + 0.05,
        "CAP mean staleness {cap:.3} should be ≤ SSP's {ssp:.3} (paper §2.1)"
    );
    eprintln!("mean observed staleness: ssp(s=4) = {ssp:.3}, cap(s=4) = {cap:.3}");
}
