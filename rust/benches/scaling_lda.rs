//! E1 + E2 — the paper's evaluation section.
//!
//! **Table 1**: summary statistics of the (synthetic) 20News corpus —
//! printed at full scale from the seeded generator.
//!
//! **Figure 5**: LDA strong scaling. The paper fixes K = 2000 topics and
//! sweeps 8→32 cores on 8 nodes, plotting speedup vs ideal linear. We run
//! the same sweep shape on the simulated cluster (scaled corpus + topic
//! count per DESIGN.md §3): workers ∈ {1, 2, 4, 8}, weak VAP, reporting
//! tokens/s, speedup over 1 worker, and the parallel efficiency — the
//! quantities the figure plots.
//!
//! `BAPPS_FULL=1` additionally runs the paper's exact corpus scale
//! (11,269 docs / 1.318 M tokens) with K=2000 — slow; the default run
//! uses corpus/16 and K=64.

use std::sync::Arc;
use std::time::Instant;

use bapps::apps::lda::{run_lda, Corpus, LdaConfig, SyntheticCorpusConfig};
use bapps::config::{NetConfig, PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;

fn main() {
    let full = std::env::var("BAPPS_FULL").is_ok();

    // ---------------- Table 1 ----------------
    println!("# E1 — Table 1: corpus summary statistics\n");
    let t0 = Instant::now();
    let full_corpus = Corpus::synthetic(&SyntheticCorpusConfig::news20());
    let stats = full_corpus.stats();
    println!("{stats}");
    println!(
        "\n(paper: 11269 docs / 53485 words / 1318299 tokens; generated in {:.1}s)\n",
        t0.elapsed().as_secs_f64()
    );
    assert_eq!(stats.num_docs, 11_269);
    assert_eq!(stats.num_tokens, 1_318_299);
    drop(full_corpus);

    // ---------------- Figure 5 ----------------
    println!("# E2 — Figure 5: LDA strong scaling (weak VAP)\n");
    let (scale, topics, sweeps) = if full { (1, 2000, 1) } else { (16, 64, 2) };
    let corpus = Arc::new(Corpus::synthetic(&SyntheticCorpusConfig::news20_scaled(scale)));
    println!(
        "workload: corpus 1/{scale} ({} tokens), K={topics}, {sweeps} sweeps, policy wvap(8)\n",
        corpus.stats().num_tokens
    );
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 8 {
        println!(
            "NOTE: this host exposes {cores} core(s). Wall-clock speedup beyond \
             {cores}x is physically impossible here, so the table below measures \
             the COORDINATION OVERHEAD of adding workers (retained throughput; \
             1.0 = free coordination). On a >=8-core testbed the same code \
             produces the paper's speedup shape; the paper's own Fig 5 ran \
             8->32 cores across 8 nodes.\n"
        );
    }
    println!("| workers | tokens/s | vs 1 worker | ideal (multicore) | retained |");
    println!("|---------|----------|-------------|-------------------|----------|");

    let mut base_tps = None;
    for workers in [1u32, 2, 4, 8] {
        let procs = if workers >= 2 { 2 } else { 1 };
        let sys = PsSystem::launch(
            SystemConfig::builder()
                .num_server_shards(2)
                .num_client_procs(procs)
                .threads_per_proc(workers / procs)
                .net(NetConfig::lan_40gbe()) // the paper's 40 GbE profile
                .flush_interval_us(100)
                .build(),
        )
        .unwrap();
        let res = run_lda(
            &sys,
            corpus.clone(),
            LdaConfig {
                num_topics: topics,
                sweeps,
                policy: PolicyConfig::Vap { v_thr: 8.0, strong: false },
                seed: 7,
                use_xla: false,
                ..LdaConfig::default()
            },
            None,
        )
        .unwrap();
        let tps = res.tokens_per_sec;
        let base = *base_tps.get_or_insert(tps);
        let speedup = tps / base;
        let ideal = workers as f64;
        println!(
            "| {workers:>7} | {tps:>8.0} | {speedup:>11.2} | {ideal:>17.0} | {:>7.0}% |",
            100.0 * speedup
        );
        sys.shutdown().unwrap();
    }

    println!(
        "\nshape check (paper Fig 5): on a multicore testbed the speedup curve \
         bends below ideal as contention on the shared word-topic table \
         grows. On this single-core host the same contention shows up as the \
         'retained' column staying below 100%: the gap is the coordination \
         cost (locks, acks, consistency gates) the paper's models trade \
         against staleness."
    );
}
