//! E3 + E5 + E6 — consistency-model ablations.
//!
//! **E3 (Figure 1)**: replay the paper's exact VAP update stream with the
//! trace recorder on and print the resulting timeline — the textual
//! regeneration of Figure 1.
//!
//! **E5**: throughput vs consistency model with straggler injection —
//! the paper's core claim (§1): best-effort is fast but unsafe, BSP/SSP
//! are safe but stall behind stragglers, the bounded-asynchronous models
//! keep throughput while staying safe.
//!
//! **E6**: magnitude-priority vs FIFO update scheduling (§4.2 "we by
//! default prioritize updates with larger magnitude") — SGD convergence
//! at equal wall-clock with a constrained network.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bapps::apps::sgd::{run_sgd, LogRegData, LogRegDataConfig, SgdConfig};
use bapps::config::{NetConfig, PolicyConfig, StragglerConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::table::{RowId, RowKind, TableDesc, TableId};

fn fig1() {
    println!("# E3 — Figure 1: VAP blocking timeline (v_thr = 8)\n");
    let sys = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(1)
            .num_client_procs(2)
            .threads_per_proc(1)
            .net(NetConfig { latency_us: 3_000, bandwidth_bps: 0, jitter_us: 0, seed: 1 })
            .flush_interval_us(50)
            .trace(true)
            .build(),
    )
    .unwrap();
    sys.create_table(TableDesc {
        id: TableId(0),
        num_rows: 4,
        row_width: 4,
        row_kind: RowKind::Dense,
        policy: PolicyConfig::Vap { v_thr: 8.0, strong: false },
    })
    .unwrap();
    sys.run_workers(move |ctx| {
        if ctx.worker_id().0 != 0 {
            return;
        }
        let t = ctx.table(TableId(0));
        for d in [1.0f32, 3.0, 2.0, 1.0, 1.0, 2.0] {
            t.inc(RowId(0), 0, d).unwrap();
        }
        std::thread::sleep(Duration::from_millis(50));
    })
    .unwrap();
    println!("{}", sys.trace().render());
    println!("(compare: updates 1-5 sum to 8 = v_thr; the 6th (value 2) blocks");
    println!(" until visibility acks release earlier updates — paper Fig 1)\n");
    sys.shutdown().unwrap();
}

/// A synthetic iterate-and-update workload measured over a FIXED time
/// window: every worker loops [read hot row, compute (straggler-scaled),
/// write, clock] until the deadline; we report the **non-straggler**
/// workers' aggregate iterations/second — the paper's question is how
/// much progress healthy workers retain when one peer is slow.
fn policy_throughput(policy: PolicyConfig, straggle: bool) -> f64 {
    let workers = 4u32;
    let stragglers = if straggle {
        StragglerConfig { workers: vec![0], slowdown: 10.0 }
    } else {
        StragglerConfig::default()
    };
    let sys = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(workers / 2)
            .net(NetConfig::lan_40gbe())
            .stragglers(stragglers)
            .flush_interval_us(100)
            .wait_timeout_ms(60_000)
            .build(),
    )
    .unwrap();
    sys.create_table(TableDesc {
        id: TableId(0),
        num_rows: 64,
        row_width: 8,
        row_kind: RowKind::Dense,
        policy,
    })
    .unwrap();
    let window = Duration::from_millis(1200);
    let counts = sys
        .run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            let deadline = Instant::now() + window;
            let mut iters = 0u64;
            let mut i = 0u64;
            while Instant::now() < deadline {
                let _ = t.get_row(RowId(i % 64)).unwrap();
                // "compute": 400 µs, 10× for the straggler
                ctx.straggle(Duration::from_micros(400));
                t.inc(RowId(i % 64), (i % 8) as u32, 0.1).unwrap();
                ctx.clock().unwrap();
                iters += 1;
                i += 1;
            }
            (ctx.is_straggler(), iters)
        })
        .unwrap();
    sys.shutdown().unwrap();
    let healthy: u64 = counts.iter().filter(|(s, _)| !s).map(|(_, n)| n).sum();
    healthy as f64 / window.as_secs_f64()
}

fn ablation_policies() {
    println!("# E5 — throughput vs consistency model (4 workers, 40GbE sim)\n");
    println!("| policy            | healthy iters/s (clean) | healthy iters/s (straggler) | retained |");
    println!("|-------------------|-------------------------|------------------------------|----------|");
    for policy in [
        PolicyConfig::Bsp,
        PolicyConfig::Ssp { staleness: 2 },
        PolicyConfig::Cap { staleness: 2 },
        PolicyConfig::Vap { v_thr: 8.0, strong: false },
        PolicyConfig::Vap { v_thr: 8.0, strong: true },
        PolicyConfig::Cvap { staleness: 2, v_thr: 8.0, strong: false },
        PolicyConfig::BestEffort,
    ] {
        let clean = policy_throughput(policy, false);
        let strag = policy_throughput(policy, true);
        println!(
            "| {:<17} | {clean:>23.0} | {strag:>28.0} | {:>7.0}% |",
            policy.name(),
            100.0 * strag / clean
        );
    }
    println!(
        "\nshape check (paper §1/§2): every clock-bounded model (BSP/SSP/CAP/\
         CVAP) throttles healthy workers to ~the straggler's pace — the s \
         bound is the binding constraint whatever the propagation \
         discipline. The value-bounded models (VAP) and best-effort retain \
         most of their throughput: a slow peer only bounds ITS OWN unsynced \
         updates, not the others' progress — which is exactly why the paper \
         introduces value bounds for straggler-heavy clusters, and CVAP when \
         you additionally need clock guarantees (and accept the throttle).\n"
    );
}

fn ablation_priority() {
    println!("# E6 — magnitude-priority vs FIFO update scheduling (§4.2)\n");
    // Constrained network: 2 MB/s, so only part of the egress drains per
    // flush; priority decides WHICH updates ship first.
    println!("| scheduling | final loss | accuracy | bytes sent |");
    println!("|------------|------------|----------|------------|");
    for magnitude in [true, false] {
        let sys = PsSystem::launch(
            SystemConfig::builder()
                .num_server_shards(1)
                .num_client_procs(2)
                .threads_per_proc(1)
                .net(NetConfig {
                    latency_us: 100,
                    bandwidth_bps: 2_000_000,
                    jitter_us: 0,
                    seed: 5,
                })
                .flush_interval_us(100)
                .max_batch_updates(8) // small batches: ordering matters
                .magnitude_priority(magnitude)
                .build(),
        )
        .unwrap();
        let data = Arc::new(LogRegData::synthetic(&LogRegDataConfig {
            n: 4096,
            d: 256, // wide: many rows per gradient, partial flushes
            noise: 0.02,
            seed: 31,
        }));
        let res = run_sgd(
            &sys,
            data,
            SgdConfig {
                iters: 60,
                batch: 32,
                policy: PolicyConfig::BestEffort, // isolate the scheduling effect
                eta: Some(0.2),
                ..SgdConfig::default()
            },
            None,
        )
        .unwrap();
        let bytes = sys.net_metrics().bytes_sent();
        println!(
            "| {:<10} | {:>10.4} | {:>8.3} | {bytes:>10} |",
            if magnitude { "magnitude" } else { "fifo" },
            res.final_loss,
            res.accuracy
        );
        sys.shutdown().unwrap();
    }
    println!(
        "\nshape check: magnitude-first ships the gradient mass that moves \
         the model; at equal step counts it converges at least as well per \
         byte (paper §4.2's rationale).\n"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let only = args.iter().find(|a| ["fig1", "policies", "priority"].contains(&a.as_str()));
    match only.map(|s| s.as_str()) {
        Some("fig1") => fig1(),
        Some("policies") => ablation_policies(),
        Some("priority") => ablation_priority(),
        _ => {
            fig1();
            ablation_policies();
            ablation_priority();
        }
    }
}
