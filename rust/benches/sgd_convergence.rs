//! E4 — Theorem 1 empirically: SGD-under-VAP regret vs the paper's bound
//! `R[X] ≤ σL²√T + (F²/σ)√T + 2σL·v_thr·P·√T`, with `σ = F/(L√(v_thr·P))`
//! and `η_t = σ/√t`.
//!
//! Three checks, printed as tables:
//!  1. the measured regret sits under the bound for every (v_thr, P);
//!  2. `R[X]/T` decreases as `T` grows (the `O(√T)` ⇒ convergence claim);
//!  3. larger `v_thr` ⇒ larger regret constant (the consistency/progress
//!     trade-off the paper's models let applications tune).

use std::sync::Arc;

use bapps::apps::sgd::{run_sgd, LogRegData, LogRegDataConfig, SgdConfig};
use bapps::config::{PolicyConfig, SystemConfig};
use bapps::consistency::cvap::theorem1_regret_bound;
use bapps::coordinator::PsSystem;

const L: f64 = 4.0;
const F: f64 = 4.0;

/// Run SGD and return (regret, T, final accuracy). Regret is measured on
/// the workers' noisy views against the planted separator's loss
/// (≈ f(x*)).
fn measure(v_thr: f32, workers: u32, iters: usize, data: &Arc<LogRegData>) -> (f64, u64, f64) {
    let procs = if workers >= 2 { 2 } else { 1 };
    let sys = PsSystem::launch(
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(procs)
            .threads_per_proc(workers / procs)
            .flush_interval_us(100)
            .build(),
    )
    .unwrap();
    let res = run_sgd(
        &sys,
        data.clone(),
        SgdConfig {
            iters,
            batch: 32,
            policy: PolicyConfig::Vap { v_thr, strong: false },
            lipschitz: L,
            diameter: F,
            eta: None, // Theorem-1 schedule
            use_xla: false,
            seed: 17,
        },
        None,
    )
    .unwrap();
    sys.shutdown().unwrap();
    let f_star = data.loss(&data.w_true);
    let t = (iters as u64) * workers as u64;
    // loss_curve[i] is the mean over workers at iteration i ⇒ summing it
    // and multiplying by P gives Σ_t f_t(x̃_t).
    let regret: f64 =
        res.loss_curve.iter().map(|l| (l - f_star).max(0.0)).sum::<f64>() * workers as f64;
    (regret, t, res.accuracy)
}

fn main() {
    let data = Arc::new(LogRegData::synthetic(&LogRegDataConfig {
        n: 8192,
        d: 64,
        noise: 0.02,
        seed: 13,
    }));

    println!("# E4 — SGD regret under VAP vs the Theorem-1 bound\n");
    println!("| v_thr | P | T    | regret R[X] | bound  | within | R[X]/T | acc   |");
    println!("|-------|---|------|-------------|--------|--------|--------|-------|");
    for &(v_thr, workers) in &[(1.0f32, 2u32), (4.0, 2), (16.0, 2), (4.0, 4)] {
        let iters = 150;
        let (regret, t, acc) = measure(v_thr, workers, iters, &data);
        let bound = theorem1_regret_bound(t, L, F, v_thr as f64, workers);
        println!(
            "| {v_thr:>5} | {workers} | {t:>4} | {regret:>11.1} | {bound:>6.0} | {:>6} | {:>6.4} | {acc:.3} |",
            regret <= bound,
            regret / t as f64
        );
    }

    println!("\n## R[X]/T decay with T (the convergence claim)\n");
    println!("| T    | R[X]/T |");
    println!("|------|--------|");
    let mut prev = f64::INFINITY;
    let mut decays = true;
    for iters in [40usize, 160, 640] {
        let (regret, t, _) = measure(4.0, 2, iters, &data);
        let per_t = regret / t as f64;
        println!("| {t:>4} | {per_t:>6.4} |");
        if per_t > prev * 1.15 {
            decays = false; // allow 15% noise
        }
        prev = per_t;
    }
    println!(
        "\nshape check: R[X]/T {} with T (Theorem 1 ⇒ E[f_t(x̃_t)−f(x*)] → 0).",
        if decays { "decays" } else { "did NOT decay (investigate!)" }
    );
}
