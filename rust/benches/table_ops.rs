//! E7 — microbenchmarks of the PS hot paths: `Inc`/`Get` through the
//! client cache hierarchy, egress drain, vector-clock ticks, and routing.
//!
//! Self-harnessed (criterion is unavailable offline): warmup + N timed
//! repetitions, reporting ns/op and ops/s. Run via `cargo bench` or
//! `cargo bench --bench table_ops`.

use std::time::Instant;

use bapps::clock::VectorClock;
use bapps::comm::priority::{DrainOrder, UpdateQueue};
use bapps::config::{PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::table::{RowId, RowKind, RowUpdate, TableDesc, TableId};

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // warmup
    let _ = f();
    let mut best = f64::INFINITY;
    let mut total_ops = 0u64;
    let mut total_secs = 0.0;
    for _ in 0..3 {
        let t0 = Instant::now();
        let ops = f();
        let dt = t0.elapsed().as_secs_f64();
        total_ops += ops;
        total_secs += dt;
        let ns = dt * 1e9 / ops as f64;
        if ns < best {
            best = ns;
        }
    }
    println!(
        "| {name:<38} | {best:>9.1} ns/op | {:>12.0} ops/s |",
        total_ops as f64 / total_secs
    );
}

fn main() {
    println!("# E7 — table/cache/clock microbenchmarks\n");
    println!("| benchmark                              |      best ns/op |   mean ops/s |");
    println!("|----------------------------------------|-----------------|--------------|");

    // ---- in-process PS: Inc through the thread-cache write path ----
    for policy in [
        PolicyConfig::BestEffort,
        PolicyConfig::Cap { staleness: 2 },
        PolicyConfig::Vap { v_thr: 1e9, strong: false }, // gate never blocks
    ] {
        let sys = PsSystem::launch(
            SystemConfig::builder()
                .num_server_shards(2)
                .num_client_procs(1)
                .threads_per_proc(1)
                .flush_interval_us(200)
                .build(),
        )
        .unwrap();
        sys.create_table(TableDesc {
            id: TableId(0),
            num_rows: 1024,
            row_width: 16,
            row_kind: RowKind::Dense,
            policy,
        })
        .unwrap();
        let name = format!("inc [{}]", policy.name());
        sys.run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            // measured inside the worker; print from here
            let mut best = f64::INFINITY;
            let mut total_ops = 0u64;
            let mut total_secs = 0.0;
            for rep in 0..4 {
                const N: u64 = 200_000;
                let t0 = Instant::now();
                for i in 0..N {
                    t.inc(RowId(i % 1024), (i % 16) as u32, 1.0).unwrap();
                }
                let dt = t0.elapsed().as_secs_f64();
                if rep > 0 {
                    total_ops += N;
                    total_secs += dt;
                    best = best.min(dt * 1e9 / N as f64);
                }
                ctx.clock().unwrap();
            }
            println!(
                "| {name:<38} | {best:>9.1} ns/op | {:>12.0} ops/s |",
                total_ops as f64 / total_secs
            );
        })
        .unwrap();
        sys.shutdown().unwrap();
    }

    // ---- Get from a warm cache (clock gate passes locally) ----
    {
        let sys = PsSystem::launch(
            SystemConfig::builder()
                .num_server_shards(2)
                .num_client_procs(1)
                .threads_per_proc(1)
                .flush_interval_us(200)
                .build(),
        )
        .unwrap();
        sys.create_table(TableDesc {
            id: TableId(0),
            num_rows: 1024,
            row_width: 16,
            row_kind: RowKind::Dense,
            policy: PolicyConfig::Cap { staleness: 8 },
        })
        .unwrap();
        sys.run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            for i in 0..1024u64 {
                t.inc(RowId(i), 0, 1.0).unwrap();
            }
            ctx.clock().unwrap();
            let mut best = f64::INFINITY;
            let mut total_ops = 0u64;
            let mut total_secs = 0.0;
            for rep in 0..4 {
                const N: u64 = 200_000;
                let t0 = Instant::now();
                let mut acc = 0.0f32;
                for i in 0..N {
                    acc += t.get(RowId(i % 1024), (i % 16) as u32).unwrap();
                }
                std::hint::black_box(acc);
                let dt = t0.elapsed().as_secs_f64();
                if rep > 0 {
                    total_ops += N;
                    total_secs += dt;
                    best = best.min(dt * 1e9 / N as f64);
                }
            }
            println!(
                "| {:<38} | {best:>9.1} ns/op | {:>12.0} ops/s |",
                "get [cap(s=8), warm cache]",
                total_ops as f64 / total_secs
            );
            // row-granular read
            let mut best = f64::INFINITY;
            let mut total_ops = 0u64;
            let mut total_secs = 0.0;
            for rep in 0..4 {
                const N: u64 = 50_000;
                let t0 = Instant::now();
                for i in 0..N {
                    std::hint::black_box(t.get_row(RowId(i % 1024)).unwrap());
                }
                let dt = t0.elapsed().as_secs_f64();
                if rep > 0 {
                    total_ops += N;
                    total_secs += dt;
                    best = best.min(dt * 1e9 / N as f64);
                }
            }
            println!(
                "| {:<38} | {best:>9.1} ns/op | {:>12.0} ops/s |",
                "get_row[16] (warm cache)",
                total_ops as f64 / total_secs
            );
        })
        .unwrap();
        sys.shutdown().unwrap();
    }

    // ---- pure data-structure paths ----
    bench("update_queue push+merge (mag order)", || {
        let mut q = UpdateQueue::new(DrainOrder::Magnitude);
        const N: u64 = 300_000;
        for i in 0..N {
            q.push(RowId(i % 512), RowUpdate::single((i % 8) as u32, i as f32));
        }
        std::hint::black_box(q.drain_all());
        N
    });
    bench("update_queue drain(128) cycle", || {
        let mut q = UpdateQueue::new(DrainOrder::Magnitude);
        const N: u64 = 100_000;
        for i in 0..N {
            q.push(RowId(i % 4096), RowUpdate::single(0, i as f32));
        }
        let mut out = 0u64;
        while !q.is_empty() {
            out += q.drain(128).len() as u64;
        }
        std::hint::black_box(out);
        N
    });
    bench("vector_clock tick (64 workers)", || {
        let mut vc = VectorClock::new(0u32..64);
        const N: u64 = 1_000_000;
        for i in 0..N {
            vc.tick((i % 64) as u32);
        }
        std::hint::black_box(vc.min_clock());
        N
    });
    bench("shard routing hash", || {
        let desc = TableDesc {
            id: TableId(3),
            num_rows: 1 << 20,
            row_width: 8,
            row_kind: RowKind::Dense,
            policy: PolicyConfig::Bsp,
        };
        const N: u64 = 2_000_000;
        let mut acc = 0u32;
        for i in 0..N {
            acc ^= desc.shard_of(RowId(i), 8).0;
        }
        std::hint::black_box(acc);
        N
    });
    println!("\ndone.");
}
