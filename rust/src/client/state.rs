//! Per-table client-side state: snapshot, overlay, egress, VAP accounting.
//!
//! All methods are synchronous over `&mut self`; the surrounding
//! [`super::core::ClientCore`] wraps a [`TableState`] in a mutex+condvar
//! pair. Keeping the state logic lock-free makes it directly unit- and
//! property-testable.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::comm::batcher::Batcher;
use crate::comm::msg::{PushBatch, ServerPushBatch};
use crate::comm::priority::{DrainOrder, UpdateQueue};
use crate::consistency::ConsistencyModel;
use crate::table::{RowData, RowId, RowUpdate, TableDesc, TableStore};
use crate::trace::TraceCtx;
use crate::types::{Clock, ProcId, ShardId};

/// A sent-but-not-yet-echoed batch kept for read-my-writes — and, since
/// the crash-recovery work, for retransmission: the entry keeps the
/// clock the batch was originally stamped with so a resend after a
/// shard restart carries the *same* logical position (replay must not
/// move updates forward in time, or the staleness bound would lie).
struct OverlayEntry {
    batch_id: u64,
    clock: Clock,
    /// Shared with the sent `PushBatch` (recording/retransmitting an
    /// overlay entry clones the `Arc`, not the update list).
    updates: Arc<Vec<(RowId, RowUpdate)>>,
    /// The batch's minted trace context; a retransmission carries the
    /// *original* context so its span tree stays one tree.
    trace: TraceCtx,
}

/// Client-side state of one table in one process.
pub struct TableState {
    /// Table descriptor.
    pub desc: TableDesc,
    /// Compiled consistency policy.
    pub model: ConsistencyModel,
    num_shards: u32,
    /// Process cache: server-derived snapshot rows.
    snapshot: TableStore,
    /// Per-shard freshness floor from `MinClock` broadcasts.
    shard_clock: Vec<Clock>,
    /// Sent-but-unconfirmed own batches, FIFO per shard.
    overlay: HashMap<ShardId, VecDeque<OverlayEntry>>,
    /// Unsent updates, aggregated per row.
    egress: UpdateQueue,
    /// VAP accounting: **signed accumulated sum** of unsynchronized
    /// updates per parameter (paper §2.2; only maintained when the policy
    /// has a value bound). Signed so +δ/−δ churn (LDA count oscillation)
    /// does not consume divergence budget.
    pending_sum: HashMap<(RowId, u32), f32>,
    /// Per sent batch: the signed per-parameter deltas it carries
    /// (released on `VisibilityAck`).
    batch_mags: HashMap<u64, Vec<((RowId, u32), f32)>>,
    /// Outstanding pulls: row → highest requested freshness.
    pub inflight_pulls: HashMap<RowId, Clock>,
    /// Highest server-push batch id applied per `(shard, origin)`. The
    /// forwarded stream per link is FIFO and deduplicated server-side, so
    /// a max suffices; it answers a recovered shard's `AckProbe` ("did
    /// you see this batch?") and shields the overlay from duplicates.
    applied_from: HashMap<(ShardId, ProcId), u64>,
    /// This process (for rebuilding batches on retransmission).
    origin: ProcId,
    /// Last announced incarnation per shard; stamps outgoing batches.
    /// Lives *here* (under the table lock) rather than on the core so
    /// that a resync can atomically bump the epoch and retransmit the
    /// overlay — a flush racing ahead with the new epoch would otherwise
    /// advance the server's per-origin dedup watermark past the
    /// retransmissions and orphan them.
    shard_epochs: Vec<u32>,
    /// Batch assembly.
    batcher: Batcher,
    /// Largest delta magnitude this process wrote (diagnostics: paper's u).
    pub u_local: f32,
    /// Trace time (µs) the oldest currently-unsent update entered the
    /// egress queue — the open edge of the next `batch` span. `None`
    /// while the queue is empty; the core stamps it on the first `inc`
    /// after a drain.
    pub egress_since_us: Option<u64>,
}

impl TableState {
    /// Fresh state for `desc` in process `origin`.
    pub fn new(
        desc: TableDesc,
        origin: ProcId,
        num_shards: u32,
        max_batch: usize,
        magnitude_priority: bool,
    ) -> Self {
        let model = ConsistencyModel::new(desc.policy);
        let order = if magnitude_priority { DrainOrder::Magnitude } else { DrainOrder::Fifo };
        TableState {
            model,
            snapshot: TableStore::new(desc.row_kind, desc.row_width),
            shard_clock: vec![0; num_shards as usize],
            overlay: HashMap::new(),
            egress: UpdateQueue::new(order),
            pending_sum: HashMap::new(),
            batch_mags: HashMap::new(),
            inflight_pulls: HashMap::new(),
            applied_from: HashMap::new(),
            origin,
            shard_epochs: vec![0; num_shards as usize],
            batcher: Batcher::new(origin, max_batch),
            u_local: 0.0,
            egress_since_us: None,
            num_shards,
            desc,
        }
    }

    /// The effective freshness of a cached row: the max of the stored row
    /// clock and the owning shard's broadcast floor.
    pub fn effective_clock(&self, row: RowId) -> Clock {
        let floor = self.shard_clock[self.desc.shard_of(row, self.num_shards).0 as usize];
        let row_clock = self.snapshot.get(row).map_or(0, |sr| sr.clock);
        row_clock.max(floor)
    }

    /// Does a read of `row` by a worker at `reader_clock` pass the clock
    /// gate right now?
    pub fn read_admissible(&self, row: RowId, reader_clock: Clock) -> bool {
        self.effective_clock(row) >= self.model.required_read_clock(reader_clock)
    }

    /// Signed accumulated unsynchronized sum of a parameter (VAP
    /// accounting).
    pub fn pending_mass(&self, row: RowId, col: u32) -> f32 {
        self.pending_sum.get(&(row, col)).copied().unwrap_or(0.0)
    }

    /// Does an `Inc` of `delta` on `(row, col)` pass the value gate?
    pub fn write_admissible(&self, row: RowId, col: u32, delta: f32) -> bool {
        !self.model.write_blocked(self.pending_mass(row, col), delta)
    }

    /// Record an `Inc` into the egress queue + VAP accounting. The caller
    /// must have passed the value gate first.
    pub fn apply_inc(&mut self, row: RowId, col: u32, delta: f32) {
        if self.model.v_thr().is_some() {
            *self.pending_sum.entry((row, col)).or_insert(0.0) += delta;
        }
        self.u_local = self.u_local.max(delta.abs());
        self.egress.push(row, RowUpdate::single(col, delta));
    }

    /// Record a whole-row `Inc` (dense delta).
    pub fn apply_inc_row(&mut self, row: RowId, deltas: &[f32]) {
        if self.model.v_thr().is_some() {
            for (c, d) in deltas.iter().enumerate() {
                if *d != 0.0 {
                    *self.pending_sum.entry((row, c as u32)).or_insert(0.0) += d;
                }
            }
        }
        for d in deltas {
            self.u_local = self.u_local.max(d.abs());
        }
        self.egress.push(row, RowUpdate::Dense(deltas.to_vec()));
    }

    /// Compose the visible value of `(row, col)` for this process:
    /// snapshot + sent overlay + unsent egress (read-my-writes).
    pub fn read(&self, row: RowId, col: u32) -> f32 {
        let mut v = self.snapshot.get(row).and_then(|sr| sr.data.get(col)).unwrap_or(0.0);
        if let Some(q) = self.overlay.get(&self.desc.shard_of(row, self.num_shards)) {
            for e in q {
                for (r, u) in e.updates.iter() {
                    if *r == row {
                        for (c, d) in u.iter_nonzero() {
                            if c == col {
                                v += d;
                            }
                        }
                    }
                }
            }
        }
        if let Some(u) = self.egress.get(row) {
            for (c, d) in u.iter_nonzero() {
                if c == col {
                    v += d;
                }
            }
        }
        v
    }

    /// Compose the visible value of a whole row (dense).
    pub fn read_row(&self, row: RowId) -> Vec<f32> {
        let mut v = vec![0.0; self.desc.row_width as usize];
        self.read_row_into(row, &mut v);
        v
    }

    /// Allocation-free variant of [`TableState::read_row`]: composes the
    /// row into `out` (must be `row_width` long). The LDA sampler calls
    /// this once per token — the perf pass measured the per-call `Vec`
    /// allocation at ~15% of the single-worker profile.
    pub fn read_row_into(&self, row: RowId, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.desc.row_width as usize);
        match self.snapshot.get(row) {
            Some(sr) => match sr.data.as_ref() {
                crate::table::RowData::Dense(d) => out.copy_from_slice(d),
                sparse => {
                    out.iter_mut().for_each(|x| *x = 0.0);
                    for (c, v) in sparse.to_dense(self.desc.row_width).iter().enumerate() {
                        out[c] = *v;
                    }
                }
            },
            None => out.iter_mut().for_each(|x| *x = 0.0),
        }
        if let Some(q) = self.overlay.get(&self.desc.shard_of(row, self.num_shards)) {
            for e in q {
                for (r, u) in e.updates.iter() {
                    if *r == row {
                        for (c, d) in u.iter_nonzero() {
                            if (c as usize) < out.len() {
                                out[c as usize] += d;
                            }
                        }
                    }
                }
            }
        }
        if let Some(u) = self.egress.get(row) {
            for (c, d) in u.iter_nonzero() {
                if (c as usize) < out.len() {
                    out[c as usize] += d;
                }
            }
        }
    }

    /// Drain up to `max_rows` egress rows into per-shard push batches;
    /// records overlay entries + VAP batch masses. `clock` stamps the
    /// batches (the lowest possible stamp of contained updates = current
    /// proc min clock + 1); `now` (trace µs) is the seal time minted into
    /// each batch's trace context. Returns `(shard, batch)` pairs ready
    /// to send.
    pub fn make_push_batches(
        &mut self,
        max_rows: usize,
        clock: Clock,
        now: u64,
    ) -> Vec<(ShardId, PushBatch)> {
        let updates = self.egress.drain(max_rows);
        if updates.is_empty() {
            return Vec::new();
        }
        let mut batches =
            self.batcher.make_batches(&self.desc, self.num_shards, updates, clock, now);
        let track_mass = self.model.v_thr().is_some();
        for (shard, b) in &mut batches {
            b.epoch = self.shard_epochs[shard.0 as usize];
            self.overlay.entry(*shard).or_default().push_back(OverlayEntry {
                batch_id: b.batch_id,
                clock: b.clock,
                updates: b.updates.clone(),
                trace: b.trace,
            });
            if track_mass {
                let mut masses = Vec::new();
                for (row, u) in b.updates.iter() {
                    for (c, d) in u.iter_nonzero() {
                        masses.push(((*row, c), d));
                    }
                }
                self.batch_mags.insert(b.batch_id, masses);
            }
        }
        batches
    }

    /// True when the egress queue holds unsent updates.
    pub fn has_unsent(&self) -> bool {
        !self.egress.is_empty()
    }

    /// Record that a server push from `shard` was applied. Returns
    /// `false` (and records nothing) when the batch was already seen —
    /// the caller must then skip [`TableState::apply_server_push`] but
    /// should still re-ack, since the original ack may be what was lost.
    pub fn note_applied(&mut self, shard: ShardId, origin: ProcId, batch_id: u64) -> bool {
        match self.applied_from.get_mut(&(shard, origin)) {
            Some(m) if batch_id <= *m => false,
            Some(m) => {
                *m = batch_id;
                true
            }
            None => {
                self.applied_from.insert((shard, origin), batch_id);
                true
            }
        }
    }

    /// Has a server push `(origin, batch_id)` from `shard` been applied?
    /// (The answer a recovered shard's `AckProbe` asks for.)
    pub fn already_applied(&self, shard: ShardId, origin: ProcId, batch_id: u64) -> bool {
        self.applied_from.get(&(shard, origin)).map_or(false, |&m| batch_id <= m)
    }

    /// Adopt a shard's announced incarnation: subsequent batches to it
    /// carry `epoch`. Must be called (under the table lock) *before*
    /// retransmitting the overlay — see the field comment.
    pub fn set_shard_epoch(&mut self, shard: ShardId, epoch: u32) {
        let e = &mut self.shard_epochs[shard.0 as usize];
        if epoch > *e {
            *e = epoch;
        }
    }

    /// Rebuild the sent-but-unechoed batches for `shard`, in batch-id
    /// order, stamped with the shard's **new** `epoch` but their
    /// **original** clocks. Called on `ShardRecovered`: everything the
    /// crashed shard may have lost is exactly this queue (echoed batches
    /// were durably logged before the echo was sent).
    pub fn retransmit_batches(&self, shard: ShardId, epoch: u32) -> Vec<PushBatch> {
        self.overlay.get(&shard).map_or_else(Vec::new, |q| {
            q.iter()
                .map(|e| PushBatch {
                    table: self.desc.id,
                    origin: self.origin,
                    batch_id: e.batch_id,
                    updates: e.updates.clone(),
                    clock: e.clock,
                    epoch,
                    trace: e.trace,
                })
                .collect()
        })
    }

    /// Outstanding pulls whose row lives on `shard`, as
    /// `(row, needed clock)` pairs sorted by row id (re-issued after the
    /// shard recovers, since the original request may have died with it).
    pub fn pulls_on_shard(&self, shard: ShardId) -> Vec<(RowId, Clock)> {
        let mut v: Vec<(RowId, Clock)> = self
            .inflight_pulls
            .iter()
            .filter(|(row, _)| self.desc.shard_of(**row, self.num_shards) == shard)
            .map(|(row, c)| (*row, *c))
            .collect();
        v.sort_by_key(|(row, _)| row.0);
        v
    }

    /// Apply a server push. For foreign batches: apply deltas to the
    /// snapshot. For the echo of an own batch: pop the matching overlay
    /// entry and apply the deltas (net read value unchanged — the deltas
    /// move from overlay to snapshot atomically under the caller's lock).
    /// Touched rows' clocks rise to the push's `min_clock`.
    pub fn apply_server_push(&mut self, own_proc: ProcId, push: &ServerPushBatch) {
        if push.origin == own_proc {
            // FIFO per shard link ⇒ echoes arrive in overlay order.
            let shard = push
                .updates
                .first()
                .map(|(r, _)| self.desc.shard_of(*r, self.num_shards));
            if let Some(shard) = shard {
                if let Some(q) = self.overlay.get_mut(&shard) {
                    if let Some(front) = q.front() {
                        debug_assert_eq!(
                            front.batch_id, push.batch_id,
                            "echo out of order on shard link"
                        );
                        if front.batch_id == push.batch_id {
                            q.pop_front();
                        }
                    }
                }
            }
        }
        for (row, u) in push.updates.iter() {
            self.snapshot.apply(*row, u);
            self.snapshot.bump_clock(*row, push.min_clock);
        }
    }

    /// Install a pull reply (full-row snapshot). The data `Arc` comes
    /// straight off the wire message — installing it is clone-free.
    pub fn apply_pull_reply(&mut self, row: RowId, data: Arc<RowData>, clock: Clock) {
        self.snapshot.install(row, data, clock);
        if let Some(needed) = self.inflight_pulls.get(&row).copied() {
            if clock >= needed {
                self.inflight_pulls.remove(&row);
            }
        }
    }

    /// Raise a shard's freshness floor from a `MinClock` broadcast.
    pub fn apply_min_clock(&mut self, shard: ShardId, clock: Clock) {
        let s = &mut self.shard_clock[shard.0 as usize];
        if clock > *s {
            *s = clock;
        }
    }

    /// Release a batch's mass on `VisibilityAck` (VAP). Returns true if
    /// any mass was released (worth waking writers).
    pub fn apply_visibility_ack(&mut self, batch_id: u64) -> bool {
        match self.batch_mags.remove(&batch_id) {
            Some(masses) => {
                for (param, m) in masses {
                    // The entry may be legitimately absent at zero (signed
                    // cancellation) while this batch was still in flight —
                    // the subtraction must happen regardless, or the
                    // ledger leaks permanently.
                    let e = self.pending_sum.entry(param).or_insert(0.0);
                    *e -= m;
                    if e.abs() <= 1e-12 {
                        self.pending_sum.remove(&param);
                    }
                }
                true
            }
            None => false,
        }
    }

    /// Debug introspection: `(snapshot value, snapshot row clock, shard
    /// floor, overlay contribution, egress contribution)` for one param.
    #[doc(hidden)]
    pub fn debug_param(&self, row: RowId, col: u32) -> (f32, Clock, Clock, f32, f32) {
        let snap_v = self.snapshot.get(row).and_then(|sr| sr.data.get(col)).unwrap_or(0.0);
        let snap_c = self.snapshot.get(row).map_or(0, |sr| sr.clock);
        let floor = self.shard_clock[self.desc.shard_of(row, self.num_shards).0 as usize];
        let mut overlay_v = 0.0;
        if let Some(q) = self.overlay.get(&self.desc.shard_of(row, self.num_shards)) {
            for e in q {
                for (r, u) in e.updates.iter() {
                    if *r == row {
                        for (c, d) in u.iter_nonzero() {
                            if c == col {
                                overlay_v += d;
                            }
                        }
                    }
                }
            }
        }
        let mut egress_v = 0.0;
        if let Some(u) = self.egress.get(row) {
            for (c, d) in u.iter_nonzero() {
                if c == col {
                    egress_v += d;
                }
            }
        }
        (snap_v, snap_c, floor, overlay_v, egress_v)
    }

    /// Snapshot-row count (diagnostics).
    pub fn cached_rows(&self) -> usize {
        self.snapshot.len()
    }

    /// Invariant check (debug harness): for every param,
    /// `pending_sum == egress contribution + unacked batch contribution`.
    /// Panics with `tag` on the first violation.
    #[doc(hidden)]
    pub fn assert_balance(&self, tag: &str) {
        use std::collections::HashMap as Map;
        let mut model: Map<(u64, u32), f32> = Map::new();
        for (row, u) in self.egress.iter() {
            for (c, d) in u.iter_nonzero() {
                *model.entry((row.0, c)).or_insert(0.0) += d;
            }
        }
        for masses in self.batch_mags.values() {
            for ((row, c), m) in masses {
                *model.entry((row.0, *c)).or_insert(0.0) += m;
            }
        }
        for (&(row, col), &v) in &self.pending_sum {
            let m = model.get(&(row.0, col)).copied().unwrap_or(0.0);
            assert!(
                (v - m).abs() < 1e-3,
                "[{tag}] imbalance at r{} c{col}: pending {v} vs model {m}",
                row.0
            );
        }
        for (&(row, col), &m) in &model {
            let v = self.pending_sum.get(&(RowId(row), col)).copied().unwrap_or(0.0);
            assert!(
                (v - m).abs() < 1e-3,
                "[{tag}] imbalance at r{row} c{col}: pending {v} vs model {m}"
            );
        }
    }

    /// Total |pending| mass across all params (diagnostics: must return
    /// to 0 when the system quiesces).
    pub fn total_pending(&self) -> f64 {
        self.pending_sum.values().map(|v| v.abs() as f64).sum()
    }

    /// Number of sent batches awaiting a VisibilityAck (diagnostics).
    pub fn outstanding_batches(&self) -> usize {
        self.batch_mags.len()
    }

    /// Overlay depth across shards (diagnostics: should stay small).
    pub fn overlay_depth(&self) -> usize {
        self.overlay.values().map(|q| q.len()).sum()
    }

    /// Pending egress rows awaiting flush (feeds the queue-depth gauge).
    pub fn egress_len(&self) -> usize {
        self.egress.len()
    }

    /// Take (and reset) the egress drain-order overtake count since the
    /// last call (magnitude priority only; FIFO drains report zero).
    pub fn take_reorders(&mut self) -> u64 {
        self.egress.take_reorders()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::table::{RowKind, TableId};

    fn state(policy: PolicyConfig) -> TableState {
        TableState::new(
            TableDesc {
                id: TableId(0),
                num_rows: 32,
                row_width: 4,
                row_kind: RowKind::Dense,
                policy,
            },
            ProcId(0),
            2,
            1024,
            true,
        )
    }

    fn echo(st: &TableState, batch: &PushBatch, min_clock: Clock) -> ServerPushBatch {
        let _ = st;
        ServerPushBatch {
            table: batch.table,
            origin: batch.origin,
            batch_id: batch.batch_id,
            updates: batch.updates.clone(),
            min_clock,
            trace: batch.trace,
        }
    }

    #[test]
    fn read_my_writes_through_all_three_layers() {
        let mut st = state(PolicyConfig::Cap { staleness: 1 });
        // unsent egress
        st.apply_inc(RowId(3), 1, 2.0);
        assert_eq!(st.read(RowId(3), 1), 2.0);
        // sent (overlay)
        let batches = st.make_push_batches(usize::MAX, 1, 0);
        assert_eq!(batches.len(), 1);
        assert_eq!(st.read(RowId(3), 1), 2.0, "value survives the send");
        assert_eq!(st.overlay_depth(), 1);
        // echoed (snapshot)
        let (_, b) = &batches[0];
        let e = echo(&st, b, 0);
        st.apply_server_push(ProcId(0), &e);
        assert_eq!(st.overlay_depth(), 0);
        assert_eq!(st.read(RowId(3), 1), 2.0, "value survives the echo");
    }

    #[test]
    fn foreign_push_adds_to_snapshot() {
        let mut st = state(PolicyConfig::Cap { staleness: 1 });
        st.apply_inc(RowId(3), 1, 2.0);
        let push = ServerPushBatch {
            table: TableId(0),
            origin: ProcId(9),
            batch_id: 0,
            updates: Arc::new(vec![(RowId(3), RowUpdate::single(1, 5.0))]),
            min_clock: 2,
            trace: TraceCtx::NONE,
        };
        st.apply_server_push(ProcId(0), &push);
        assert_eq!(st.read(RowId(3), 1), 7.0);
        assert_eq!(st.effective_clock(RowId(3)), 2);
    }

    #[test]
    fn clock_gate_uses_shard_floor() {
        let mut st = state(PolicyConfig::Ssp { staleness: 1 });
        let row = RowId(5);
        // reader at clock 4 requires freshness 2
        assert!(!st.read_admissible(row, 4));
        let shard = st.desc.shard_of(row, 2);
        st.apply_min_clock(shard, 2);
        assert!(st.read_admissible(row, 4));
        // the OTHER shard's floor does not help other rows
        let other = ShardId(1 - shard.0);
        let mut st2 = state(PolicyConfig::Ssp { staleness: 1 });
        st2.apply_min_clock(other, 2);
        assert!(!st2.read_admissible(row, 4));
    }

    #[test]
    fn vap_accounting_lifecycle() {
        let mut st = state(PolicyConfig::Vap { v_thr: 8.0, strong: false });
        for d in [1.0f32, 3.0, 2.0, 1.0, 1.0] {
            assert!(st.write_admissible(RowId(0), 0, d));
            st.apply_inc(RowId(0), 0, d);
        }
        assert_eq!(st.pending_mass(RowId(0), 0), 8.0);
        // Fig 1: next update of 2.0 is blocked
        assert!(!st.write_admissible(RowId(0), 0, 2.0));
        // a different parameter is unaffected
        assert!(st.write_admissible(RowId(0), 1, 2.0));

        // ship and release
        let batches = st.make_push_batches(usize::MAX, 1, 0);
        let ids: Vec<u64> = batches.iter().map(|(_, b)| b.batch_id).collect();
        assert_eq!(st.pending_mass(RowId(0), 0), 8.0, "sent ≠ synchronized");
        for id in ids {
            assert!(st.apply_visibility_ack(id));
        }
        assert_eq!(st.pending_mass(RowId(0), 0), 0.0);
        assert!(st.write_admissible(RowId(0), 0, 2.0));
    }

    #[test]
    fn visibility_ack_unknown_batch_is_noop() {
        let mut st = state(PolicyConfig::Vap { v_thr: 8.0, strong: false });
        assert!(!st.apply_visibility_ack(42));
    }

    #[test]
    fn pull_reply_clears_matching_inflight() {
        let mut st = state(PolicyConfig::Ssp { staleness: 0 });
        st.inflight_pulls.insert(RowId(1), 5);
        st.apply_pull_reply(RowId(1), Arc::new(RowData::Dense(vec![1.0; 4])), 3);
        assert!(st.inflight_pulls.contains_key(&RowId(1)), "reply too stale to clear");
        st.apply_pull_reply(RowId(1), Arc::new(RowData::Dense(vec![2.0; 4])), 5);
        assert!(!st.inflight_pulls.contains_key(&RowId(1)));
        assert_eq!(st.read(RowId(1), 0), 2.0);
        assert_eq!(st.effective_clock(RowId(1)), 5);
    }

    #[test]
    fn read_row_composes_all_layers() {
        let mut st = state(PolicyConfig::Cap { staleness: 1 });
        let push = ServerPushBatch {
            table: TableId(0),
            origin: ProcId(9),
            batch_id: 0,
            updates: Arc::new(vec![(RowId(2), RowUpdate::Dense(vec![1.0, 1.0, 1.0, 1.0]))]),
            min_clock: 0,
            trace: TraceCtx::NONE,
        };
        st.apply_server_push(ProcId(0), &push);
        st.apply_inc(RowId(2), 0, 0.5);
        st.make_push_batches(usize::MAX, 1, 0); // now in overlay
        st.apply_inc(RowId(2), 3, -1.0); // in egress
        assert_eq!(st.read_row(RowId(2)), vec![1.5, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn non_vap_tables_skip_mass_accounting() {
        let mut st = state(PolicyConfig::Cap { staleness: 1 });
        st.apply_inc(RowId(0), 0, 100.0);
        assert_eq!(st.pending_mass(RowId(0), 0), 0.0);
        assert!(st.write_admissible(RowId(0), 0, f32::MAX));
    }

    #[test]
    fn retransmit_rebuilds_unechoed_batches_with_original_clocks() {
        let mut st = state(PolicyConfig::Cap { staleness: 1 });
        st.apply_inc(RowId(3), 1, 2.0);
        let sent = st.make_push_batches(usize::MAX, 4, 0);
        assert_eq!(sent.len(), 1);
        let (shard, b) = &sent[0];
        st.apply_inc(RowId(3), 1, 1.0);
        st.make_push_batches(usize::MAX, 5, 0);

        // Both batches are unechoed: both come back, ids ordered, the
        // original clocks preserved, the caller's (new) epoch stamped.
        let re = st.retransmit_batches(*shard, 7);
        assert_eq!(re.len(), 2);
        assert_eq!((re[0].batch_id, re[0].clock, re[0].epoch), (b.batch_id, 4, 7));
        assert_eq!(re[1].clock, 5);
        assert_eq!(re[0].origin, ProcId(0));

        // Echo the first: it leaves the retransmission set.
        let e = echo(&st, b, 0);
        st.apply_server_push(ProcId(0), &e);
        assert_eq!(st.retransmit_batches(*shard, 7).len(), 1);
    }

    #[test]
    fn note_applied_dedups_and_answers_probes() {
        let mut st = state(PolicyConfig::Cap { staleness: 1 });
        let (s, o) = (ShardId(1), ProcId(3));
        assert!(!st.already_applied(s, o, 0));
        assert!(st.note_applied(s, o, 0));
        assert!(!st.note_applied(s, o, 0), "duplicate rejected");
        assert!(st.note_applied(s, o, 1));
        assert!(st.already_applied(s, o, 0));
        assert!(st.already_applied(s, o, 1));
        assert!(!st.already_applied(s, o, 2));
        // other links are independent
        assert!(!st.already_applied(ShardId(0), o, 0));
        assert!(!st.already_applied(s, ProcId(2), 0));
    }

    #[test]
    fn pulls_on_shard_filters_and_sorts() {
        let mut st = state(PolicyConfig::Ssp { staleness: 0 });
        // With 2 shards, row parity decides ownership in either routing —
        // derive shards from the descriptor rather than assuming.
        let rows = [RowId(0), RowId(1), RowId(2), RowId(3)];
        for (i, r) in rows.iter().enumerate() {
            st.inflight_pulls.insert(*r, i as Clock);
        }
        for shard in [ShardId(0), ShardId(1)] {
            let got = st.pulls_on_shard(shard);
            let want: Vec<(RowId, Clock)> = rows
                .iter()
                .enumerate()
                .filter(|(_, r)| st.desc.shard_of(**r, 2) == shard)
                .map(|(i, r)| (*r, i as Clock))
                .collect();
            assert_eq!(got, want);
        }
    }
}
