//! The client library (paper §4.2, Fig 2).
//!
//! An application process accesses shared parameters through this library.
//! It implements the paper's two-level cache hierarchy:
//!
//! * the **process cache** — one snapshot replica per table shared by all
//!   worker threads in the process, kept fresh by server pushes and pulls;
//! * the **thread/op-log layer** — each `Inc` lands in a write-back egress
//!   queue (aggregated per row) and in per-parameter VAP accounting; a
//!   worker's `Get` composes *snapshot + sent-but-unconfirmed overlay +
//!   unsent egress*, which is exactly how **read-my-writes** holds for
//!   every policy.
//!
//! The *Consistency Controller* of §4.3 lives here: each table's
//! [`crate::consistency::ConsistencyModel`] is consulted on every access —
//! the clock gate may turn a `Get` into a blocking pull, the value gate
//! may block an `Inc` until earlier updates are globally visible.
//!
//! Threads per client process:
//! * `N` application **worker threads** (driving [`WorkerCtx`]);
//! * one **ingress thread** applying server pushes / pull replies /
//!   visibility acks to the process cache and waking blocked workers;
//! * one **flusher thread** draining egress queues of eagerly-propagating
//!   tables every `flush_interval_us` ("propagates updates whenever the
//!   network bandwidth is available", §2.1).

mod core;
mod handle;
mod state;

pub use self::core::ClientCore;
pub use handle::{TableHandle, WorkerCtx};
pub use state::TableState;
