//! The per-process client core: shared caches, ingress and flusher loops.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

use crate::clock::VectorClock;
use crate::comm::msg::{Msg, Payload};
use crate::comm::{Endpoint, NetSender};
use crate::config::SystemConfig;
use crate::error::{Error, Result};
use crate::metrics::{GateMetrics, Registry, StalenessHist, WorkerMetrics};
use crate::server::TableRegistry;
use crate::table::{RowId, TableId};
use crate::trace::{BlockReason, Event, SpanKind, SpanNode, SpanSink, TraceCtx, TraceRecorder};
use crate::types::{Clock, NodeId, ProcId, ShardId, WorkerId};

use super::state::TableState;

/// Heavy accounting-invariant checks, enabled by BAPPS_BALANCE_CHECKS=1
/// (debug harness for the VAP mass ledger).
fn balance_checks() -> bool {
    static ON: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ON.get_or_init(|| std::env::var("BAPPS_BALANCE_CHECKS").is_ok())
}

/// One table's lockable state + wakeup channel.
pub(crate) struct ClientTable {
    pub state: Mutex<TableState>,
    /// Workers blocked on the clock gate (reads) or value gate (writes)
    /// wait here; the ingress thread notifies after every relevant apply.
    pub cv: Condvar,
    /// Gate denial/blocked-duration metrics for this table's policy.
    pub gate: GateMetrics,
}

/// Shared, per-process client core. Worker threads drive it through
/// [`super::WorkerCtx`]; the coordinator owns the ingress/flusher threads.
pub struct ClientCore {
    /// This process's id.
    pub proc: ProcId,
    cfg: SystemConfig,
    registry: Arc<TableRegistry>,
    net: NetSender,
    tables: RwLock<HashMap<TableId, Arc<ClientTable>>>,
    /// Thread-level vector clock; its min is this process's progress.
    vclock: Mutex<VectorClock<WorkerId>>,
    /// Per-process worker metrics (aggregated across threads).
    pub metrics: Arc<WorkerMetrics>,
    /// Observed read-staleness distribution.
    pub staleness: Arc<StalenessHist>,
    /// Trace recorder (legacy event surface may be disabled; span capture
    /// is always on).
    pub trace: Arc<TraceRecorder>,
    /// This process's span-recording lane.
    sink: SpanSink,
    /// Monotone pull-request counter (mints per-pull trace ids).
    pull_seq: AtomicU64,
    /// The process's metric registry (shared with the bus, shards and
    /// coordinator when launched through [`crate::coordinator::PsSystem`]).
    hub: Arc<Registry>,
    /// Last `ShardRecovered` incarnation seen per shard; stamps the
    /// process-level `ClockNotify` sends. (Batch stamping lives in each
    /// `TableState`, under its lock — see the field comment there.)
    shard_epochs: Vec<AtomicU32>,
    stop: AtomicBool,
}

impl ClientCore {
    /// Build the core for process `proc`. Worker ids must be registered
    /// with [`ClientCore::register_worker`] before any `Clock()` call.
    pub fn new(
        proc: ProcId,
        cfg: SystemConfig,
        registry: Arc<TableRegistry>,
        net: NetSender,
        trace: Arc<TraceRecorder>,
        hub: Arc<Registry>,
    ) -> Self {
        let shard_epochs = (0..cfg.num_server_shards).map(|_| AtomicU32::new(0)).collect();
        let sink = trace.sink(SpanNode::Client(proc));
        ClientCore {
            proc,
            cfg,
            registry,
            net,
            tables: RwLock::new(HashMap::new()),
            vclock: Mutex::new(VectorClock::empty()),
            metrics: Arc::new(WorkerMetrics::new(&hub, proc.0)),
            staleness: Arc::new(StalenessHist::new(&hub, proc.0)),
            trace,
            sink,
            pull_seq: AtomicU64::new(0),
            hub,
            shard_epochs,
            stop: AtomicBool::new(false),
        }
    }

    /// Mint the trace context for a new pull request: per-process pull
    /// counter keyed under tag 2 (pushes use tag 1), so pull and push span
    /// trees never collide.
    fn next_pull_ctx(&self) -> TraceCtx {
        let seq = self.pull_seq.fetch_add(1, Ordering::Relaxed);
        TraceCtx::mint(2, self.proc.0 as u64, seq, 0, self.trace.now_us())
    }

    /// Open the `batch` stage on the first update entering an empty
    /// egress queue (closed at the next flush's seal time).
    fn stamp_egress(&self, st: &mut TableState) {
        if st.egress_since_us.is_none() && st.has_unsent() {
            st.egress_since_us = Some(self.trace.now_us());
        }
    }

    /// System config.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The metric registry this core reports into.
    pub fn metrics_registry(&self) -> &Arc<Registry> {
        &self.hub
    }

    /// Register a worker thread in the process vector clock.
    pub fn register_worker(&self, worker: WorkerId) {
        self.vclock.lock().unwrap().register(worker);
    }

    /// This process's progress (min worker clock).
    pub fn min_clock(&self) -> Clock {
        self.vclock.lock().unwrap().min_clock()
    }

    pub(crate) fn table(&self, id: TableId) -> Result<Arc<ClientTable>> {
        if let Some(t) = self.tables.read().unwrap().get(&id) {
            return Ok(t.clone());
        }
        let desc = self.registry.get(id)?;
        let mut w = self.tables.write().unwrap();
        // Double-checked: another thread may have initialized meanwhile.
        if let Some(t) = w.get(&id) {
            return Ok(t.clone());
        }
        let gate = GateMetrics::new(self.hub.clone(), &desc.policy);
        let st = TableState::new(
            desc,
            self.proc,
            self.cfg.num_server_shards,
            self.cfg.max_batch_updates,
            self.cfg.magnitude_priority,
        );
        let t = Arc::new(ClientTable { state: Mutex::new(st), cv: Condvar::new(), gate });
        w.insert(id, t.clone());
        Ok(t)
    }

    /// ---- blocking access paths (called from worker threads) ----

    /// Clock-gated read of one element.
    pub fn get(&self, table: TableId, row: RowId, col: u32, reader_clock: Clock) -> Result<f32> {
        let t = self.table(table)?;
        let st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, Some(col))?;
        let st = self.wait_read_admissible(&t, st, row, reader_clock)?;
        self.metrics.gets.inc();
        let eff = st.effective_clock(row);
        self.staleness.record(reader_clock.saturating_sub(eff));
        Ok(st.read(row, col))
    }

    /// Clock-gated read of a whole row (densified).
    pub fn get_row(&self, table: TableId, row: RowId, reader_clock: Clock) -> Result<Vec<f32>> {
        let t = self.table(table)?;
        let st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, None)?;
        let st = self.wait_read_admissible(&t, st, row, reader_clock)?;
        self.metrics.gets.inc();
        let eff = st.effective_clock(row);
        self.staleness.record(reader_clock.saturating_sub(eff));
        Ok(st.read_row(row))
    }

    /// Allocation-free row read: composes the row into `out` (length
    /// `row_width`). Same gating as [`ClientCore::get_row`].
    pub fn get_row_into(
        &self,
        table: TableId,
        row: RowId,
        out: &mut [f32],
        reader_clock: Clock,
    ) -> Result<()> {
        let t = self.table(table)?;
        let st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, None)?;
        let st = self.wait_read_admissible(&t, st, row, reader_clock)?;
        self.metrics.gets.inc();
        st.read_row_into(row, out);
        Ok(())
    }

    /// Value-gated increment of one element.
    pub fn inc(
        &self,
        table: TableId,
        row: RowId,
        col: u32,
        delta: f32,
        worker: WorkerId,
    ) -> Result<()> {
        let t = self.table(table)?;
        let st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, Some(col))?;
        let mut st = self.wait_write_admissible(&t, st, row, col, delta, worker)?;
        st.apply_inc(row, col, delta);
        self.stamp_egress(&mut st);
        if balance_checks() {
            st.assert_balance("inc");
        }
        self.metrics.update_magnitude_max.set_max(delta.abs() as f64);
        self.metrics.incs.inc();
        Ok(())
    }

    /// Value-gated whole-row increment. Under a value bound each column's
    /// gate is awaited in column order.
    pub fn inc_row(
        &self,
        table: TableId,
        row: RowId,
        deltas: &[f32],
        worker: WorkerId,
    ) -> Result<()> {
        let t = self.table(table)?;
        let mut st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, None)?;
        if st.model.v_thr().is_some() {
            for (c, d) in deltas.iter().enumerate() {
                if *d != 0.0 {
                    st = self.wait_write_admissible(&t, st, row, c as u32, *d, worker)?;
                }
            }
        }
        st.apply_inc_row(row, deltas);
        self.stamp_egress(&mut st);
        if balance_checks() {
            st.assert_balance("inc_row");
        }
        for d in deltas {
            self.metrics.update_magnitude_max.set_max(d.abs() as f64);
        }
        self.metrics.incs.inc();
        Ok(())
    }

    /// Value-gated bulk increment: applies a whole batch of `(row, col,
    /// delta)` updates under ONE lock acquisition — the hot-path
    /// amortization the paper's thread-cache write-back buys (perf pass:
    /// per-update locking dominated the LDA sampler's profile).
    pub fn inc_many(
        &self,
        table: TableId,
        updates: &[(RowId, u32, f32)],
        worker: WorkerId,
    ) -> Result<()> {
        if updates.is_empty() {
            return Ok(());
        }
        let t = self.table(table)?;
        let mut st = t.state.lock().unwrap();
        let gated = st.model.v_thr().is_some();
        for &(row, col, delta) in updates {
            Self::check_bounds(&st, row, Some(col))?;
            if gated {
                st = self.wait_write_admissible(&t, st, row, col, delta, worker)?;
            }
            st.apply_inc(row, col, delta);
            self.metrics.update_magnitude_max.set_max(delta.abs() as f64);
        }
        self.stamp_egress(&mut st);
        self.metrics.incs.add(updates.len() as u64);
        Ok(())
    }

    /// ---- non-blocking access paths (deterministic simulation) ----
    ///
    /// The blocking paths above park workers on a condvar with wall-clock
    /// timeouts, which a virtual-time scheduler cannot drive. These
    /// variants perform the *same* gate checks and the same side effects
    /// (pull issuance on a staleness miss, flush-on-block on a value-gate
    /// miss) but return immediately, letting the simulator re-poll after
    /// delivering more messages.

    /// Non-blocking clock-gated read: `Ok(None)` when the staleness gate
    /// holds the read back (a pull with sufficient freshness has been
    /// requested; retry after ingress progress).
    pub fn try_get(
        &self,
        table: TableId,
        row: RowId,
        col: u32,
        reader_clock: Clock,
    ) -> Result<Option<f32>> {
        let t = self.table(table)?;
        let mut st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, Some(col))?;
        if !st.read_admissible(row, reader_clock) {
            t.gate.note_read_denied();
            let required = st.model.required_read_clock(reader_clock);
            let needs_pull =
                st.inflight_pulls.get(&row).map_or(true, |&needed| needed < required);
            if needs_pull {
                st.inflight_pulls.insert(row, required);
                let shard = st.desc.shard_of(row, self.cfg.num_server_shards);
                self.metrics.pulls.inc();
                let _ = self.net.send(Msg {
                    src: NodeId::Client(self.proc),
                    dst: NodeId::Server(shard),
                    payload: Payload::PullRow {
                        table,
                        row,
                        needed_clock: required,
                        worker: WorkerId(u32::MAX),
                        trace: self.next_pull_ctx(),
                    },
                });
            }
            return Ok(None);
        }
        self.metrics.gets.inc();
        let eff = st.effective_clock(row);
        self.staleness.record(reader_clock.saturating_sub(eff));
        Ok(Some(st.read(row, col)))
    }

    /// Non-blocking value-gated increment: `Ok(false)` when the write gate
    /// blocks the delta (pending mass has been flushed onto the wire so
    /// visibility can drain it; retry after ingress progress).
    pub fn try_inc(&self, table: TableId, row: RowId, col: u32, delta: f32) -> Result<bool> {
        let t = self.table(table)?;
        let mut st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, Some(col))?;
        if !st.write_admissible(row, col, delta) {
            t.gate.note_write_denied();
            // Same rationale as the blocking path: blocked mass can only
            // drain once it is on the wire.
            self.flush_locked(&mut st, usize::MAX);
            return Ok(false);
        }
        st.apply_inc(row, col, delta);
        self.stamp_egress(&mut st);
        if balance_checks() {
            st.assert_balance("try_inc");
        }
        self.metrics.update_magnitude_max.set_max(delta.abs() as f64);
        self.metrics.incs.inc();
        Ok(true)
    }

    /// Apply an increment **bypassing the VAP write gate**. This exists
    /// solely as a sabotage hook for the deterministic simulator's oracle
    /// self-tests ([`crate::sim`]): a harness that never flags a broken
    /// gate proves nothing, so the sim deliberately routes writes through
    /// here and asserts its value-bound oracle fires. Never call this from
    /// application code.
    #[doc(hidden)]
    pub fn sabotage_inc(&self, table: TableId, row: RowId, col: u32, delta: f32) -> Result<()> {
        let t = self.table(table)?;
        let mut st = t.state.lock().unwrap();
        Self::check_bounds(&st, row, Some(col))?;
        st.apply_inc(row, col, delta);
        self.stamp_egress(&mut st);
        self.metrics.update_magnitude_max.set_max(delta.abs() as f64);
        self.metrics.incs.inc();
        Ok(())
    }

    /// `Clock()` for one worker: flush every table (the SSP sync phase;
    /// for eager tables an incremental flush), tick the thread clock, and
    /// notify all shards if the process min advanced.
    pub fn clock(&self, worker: WorkerId) -> Result<Clock> {
        // Ship everything timestamped up to the current interval. The
        // flush-before-tick order is what makes `ClockNotify(m)` a valid
        // promise that all updates stamped ≤ m precede it on every link.
        self.flush_all_tables()?;
        let advanced = {
            let mut vc = self.vclock.lock().unwrap();
            vc.tick(worker)
        };
        if let Some(m) = advanced {
            for s in 0..self.cfg.num_server_shards {
                let epoch = self.shard_epochs[s as usize].load(Ordering::Relaxed);
                let _ = self.net.send(Msg {
                    src: NodeId::Client(self.proc),
                    dst: NodeId::Server(ShardId(s)),
                    payload: Payload::ClockNotify { proc: self.proc, clock: m, epoch },
                });
            }
        }
        self.metrics.clocks.inc();
        let c = self.vclock.lock().unwrap().get(worker).unwrap_or(0);
        self.trace.record(|| Event::ClockTick { at: self.trace.now_us(), worker, clock: c });
        Ok(c)
    }

    /// Flush all tables' egress queues (sync phase / shutdown drain).
    /// Tables are visited in id order so the emitted message sequence is a
    /// pure function of the system state (the deterministic simulator's
    /// trace-identity guarantee depends on it).
    pub fn flush_all_tables(&self) -> Result<()> {
        let mut ids: Vec<TableId> = self.tables.read().unwrap().keys().copied().collect();
        ids.sort_unstable_by_key(|id| id.0);
        for id in ids {
            let t = self.table(id)?;
            let mut st = t.state.lock().unwrap();
            self.flush_locked(&mut st, usize::MAX);
        }
        Ok(())
    }

    /// Flush eager tables only (flusher thread body; also driven directly
    /// by the deterministic simulator's virtual-time flusher ticks, so
    /// the CAP/VAP eager path is exercised without wall-clock threads).
    /// Id order, for the same determinism reason as
    /// [`ClientCore::flush_all_tables`].
    pub fn flush_eager_tables(&self) {
        self.flush_eager_tables_limited(self.cfg.max_batch_updates)
    }

    /// [`ClientCore::flush_eager_tables`] with an explicit per-table row
    /// cap. The sim's priority ablation drains one row per flusher tick so
    /// the magnitude-vs-FIFO egress order actually matters.
    pub fn flush_eager_tables_limited(&self, max_rows: usize) {
        let mut handles: Vec<(TableId, Arc<ClientTable>)> =
            self.tables.read().unwrap().iter().map(|(id, t)| (*id, t.clone())).collect();
        handles.sort_unstable_by_key(|(id, _)| id.0);
        for (_, t) in handles {
            let mut st = t.state.lock().unwrap();
            if st.model.eager_propagation() && st.has_unsent() {
                self.flush_locked(&mut st, max_rows);
            }
        }
    }

    /// Drain + send under the table lock (the lock ordering is what keeps
    /// `ClockNotify` behind every lower-stamped batch on each link).
    fn flush_locked(&self, st: &mut TableState, max_rows: usize) {
        if !st.has_unsent() {
            return;
        }
        if balance_checks() {
            st.assert_balance("pre_flush");
        }
        let stamp = self.min_clock() + 1; // lowest possible stamp in egress
        let now = self.trace.now_us(); // seal time: closes batch, opens net
        let batch_open = st.egress_since_us.unwrap_or(now);
        let batches = st.make_push_batches(max_rows, stamp, now);
        if balance_checks() {
            st.assert_balance("post_flush");
        }
        // A partial drain leaves updates queued: their batch stage re-opens
        // at the seal rather than keeping the (already reported) old edge.
        st.egress_since_us = if st.has_unsent() { Some(now) } else { None };
        self.metrics.egress_reorders.add(st.take_reorders());
        self.metrics.egress_rows.set(st.egress_len() as f64);
        for (shard, batch) in batches {
            let rows = batch.updates.len() as u64;
            let key = [batch.table.0 as u64, self.proc.0 as u64, batch.batch_id, rows];
            self.sink.span(SpanKind::Batch, batch.trace.id, batch_open, now, key);
            self.trace.record(|| Event::Push {
                at: now,
                proc: self.proc,
                table: batch.table,
                batch_id: batch.batch_id,
                rows: batch.updates.len(),
            });
            let _ = self.net.send(Msg {
                src: NodeId::Client(self.proc),
                dst: NodeId::Server(shard),
                payload: Payload::PushUpdates(batch),
            });
        }
    }

    fn check_bounds(st: &TableState, row: RowId, col: Option<u32>) -> Result<()> {
        if row.0 >= st.desc.num_rows {
            return Err(Error::RowOutOfRange {
                table: st.desc.id,
                row,
                num_rows: st.desc.num_rows,
            });
        }
        if let Some(c) = col {
            if c >= st.desc.row_width {
                return Err(Error::ColOutOfRange {
                    table: st.desc.id,
                    col: c,
                    width: st.desc.row_width,
                });
            }
        }
        Ok(())
    }

    fn wait_read_admissible<'a>(
        &self,
        t: &'a ClientTable,
        mut st: MutexGuard<'a, TableState>,
        row: RowId,
        reader_clock: Clock,
    ) -> Result<MutexGuard<'a, TableState>> {
        if st.read_admissible(row, reader_clock) {
            return Ok(st);
        }
        let required = st.model.required_read_clock(reader_clock);
        let deadline = crate::util::Deadline::after_ms(self.cfg.wait_timeout_ms);
        let table = st.desc.id;
        self.trace.record(|| Event::BlockStart {
            at: self.trace.now_us(),
            worker: WorkerId(u32::MAX),
            table,
            reason: BlockReason::Staleness,
        });
        t.gate.note_read_denied();
        let t0 = Instant::now();
        // Re-issue the pull with exponential backoff: the in-flight
        // request may have died with a crashed shard, and the reply is
        // idempotent (stale installs are ignored), so retrying is safe.
        let mut retry_after = Duration::from_millis(self.cfg.pull_retry_ms);
        let mut next_retry = t0 + retry_after;
        loop {
            // Ensure a pull with sufficient freshness is in flight.
            let retry = self.cfg.pull_retry_ms > 0 && Instant::now() >= next_retry;
            let needs_pull =
                retry || st.inflight_pulls.get(&row).map_or(true, |&needed| needed < required);
            if needs_pull {
                st.inflight_pulls.insert(row, required);
                let shard = st.desc.shard_of(row, self.cfg.num_server_shards);
                self.metrics.pulls.inc();
                if retry {
                    self.metrics.pull_retries.inc();
                    retry_after = retry_after.saturating_mul(2);
                }
                next_retry = Instant::now() + retry_after;
                let _ = self.net.send(Msg {
                    src: NodeId::Client(self.proc),
                    dst: NodeId::Server(shard),
                    payload: Payload::PullRow {
                        table,
                        row,
                        needed_clock: required,
                        worker: WorkerId(u32::MAX),
                        trace: self.next_pull_ctx(),
                    },
                });
            }
            let remaining = deadline.remaining(&format!(
                "read freshness {required} on table {} row {}",
                table.0, row.0
            ))?;
            let (guard, _) = t
                .cv
                .wait_timeout(st, remaining.min(Duration::from_millis(50)))
                .map_err(|_| Error::Other("poisoned table lock".into()))?;
            st = guard;
            if st.read_admissible(row, reader_clock) {
                self.metrics.add_read_block(t0.elapsed());
                t.gate.record_read_blocked_us(t0.elapsed().as_micros() as u64);
                self.trace.record(|| Event::BlockEnd {
                    at: self.trace.now_us(),
                    worker: WorkerId(u32::MAX),
                    table,
                    reason: BlockReason::Staleness,
                });
                return Ok(st);
            }
        }
    }

    fn wait_write_admissible<'a>(
        &self,
        t: &'a ClientTable,
        mut st: MutexGuard<'a, TableState>,
        row: RowId,
        col: u32,
        delta: f32,
        worker: WorkerId,
    ) -> Result<MutexGuard<'a, TableState>> {
        if st.write_admissible(row, col, delta) {
            return Ok(st);
        }
        let deadline = crate::util::Deadline::after_ms(self.cfg.wait_timeout_ms);
        let table = st.desc.id;
        self.trace.record(|| Event::BlockStart {
            at: self.trace.now_us(),
            worker,
            table,
            reason: BlockReason::ValueBound,
        });
        t.gate.note_write_denied();
        let t0 = Instant::now();
        // The blocked mass can only drain if it is on the wire: flush now.
        self.flush_locked(&mut st, usize::MAX);
        loop {
            let remaining = deadline.remaining(&format!(
                "VAP visibility on table {} row {} col {col} (pending {}, delta {delta}, overlay {}, unsent {}, unacked {})",
                table.0,
                row.0,
                st.pending_mass(row, col),
                st.overlay_depth(),
                st.has_unsent(),
                st.outstanding_batches(),
            ))?;
            let (guard, _) = t
                .cv
                .wait_timeout(st, remaining.min(Duration::from_millis(50)))
                .map_err(|_| Error::Other("poisoned table lock".into()))?;
            st = guard;
            if st.write_admissible(row, col, delta) {
                self.metrics.add_write_block(t0.elapsed());
                t.gate.record_write_blocked_us(t0.elapsed().as_micros() as u64);
                self.trace.record(|| Event::BlockEnd {
                    at: self.trace.now_us(),
                    worker,
                    table,
                    reason: BlockReason::ValueBound,
                });
                return Ok(st);
            }
        }
    }

    /// Debug: total |pending| VAP mass + unacked batch count for a table.
    #[doc(hidden)]
    pub fn debug_pending(&self, table: TableId) -> (f64, usize) {
        let t = self.table(table).unwrap();
        let st = t.state.lock().unwrap();
        (st.total_pending(), st.outstanding_batches())
    }

    /// Debug introspection of one parameter's composition (tests only).
    #[doc(hidden)]
    pub fn debug_param(&self, table: TableId, row: RowId, col: u32) -> (f32, Clock, Clock, f32, f32) {
        let t = self.table(table).unwrap();
        let st = t.state.lock().unwrap();
        st.debug_param(row, col)
    }

    /// ---- background loops (owned by the coordinator) ----

    /// Ingress loop: apply server messages to the process cache and wake
    /// blocked workers. Runs until `Shutdown` or endpoint close.
    pub fn run_ingress(self: &Arc<Self>, endpoint: Endpoint) {
        loop {
            match endpoint.recv() {
                Ok(msg) => {
                    if !self.handle_ingress(msg) {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    }

    /// Handle one ingress message (public for deterministic tests).
    /// Returns false on shutdown.
    pub fn handle_ingress(&self, msg: Msg) -> bool {
        match msg.payload {
            Payload::ServerPush(push) => {
                if let Ok(t) = self.table(push.table) {
                    let fresh = {
                        let mut st = t.state.lock().unwrap();
                        // A recovered shard may resend a batch whose first
                        // delivery survived the crash: apply exactly once.
                        let fresh = match msg.src {
                            NodeId::Server(s) => {
                                st.note_applied(s, push.origin, push.batch_id)
                            }
                            _ => true,
                        };
                        if fresh {
                            st.apply_server_push(self.proc, &push);
                        }
                        fresh
                    };
                    if fresh {
                        self.trace.record(|| Event::Applied {
                            at: self.trace.now_us(),
                            proc: self.proc,
                            table: push.table,
                            origin: push.origin,
                            batch_id: push.batch_id,
                            min_clock: push.min_clock,
                        });
                        t.cv.notify_all();
                    }
                    // Ack so the shard can track global visibility — even
                    // for a duplicate: the lost message may have been the
                    // ack itself, not the push.
                    if let NodeId::Server(_) = msg.src {
                        let _ = self.net.send(Msg {
                            src: NodeId::Client(self.proc),
                            dst: msg.src,
                            payload: Payload::PushAck {
                                table: push.table,
                                origin: push.origin,
                                batch_id: push.batch_id,
                                by: self.proc,
                            },
                        });
                    }
                }
            }
            Payload::PullReply { table, row, data, clock, trace, .. } => {
                if let Ok(t) = self.table(table) {
                    {
                        let mut st = t.state.lock().unwrap();
                        st.apply_pull_reply(row, data, clock);
                    }
                    t.cv.notify_all();
                    // The echoed context carries the issue time, so the
                    // round trip closes without a request table.
                    if !trace.is_none() {
                        self.sink.span(
                            SpanKind::Pull,
                            trace.id,
                            trace.at_us,
                            self.trace.now_us(),
                            [table.0 as u64, row.0, self.proc.0 as u64, clock as u64],
                        );
                    }
                }
            }
            Payload::MinClock { shard, clock } => {
                self.trace.record(|| Event::Floor {
                    at: self.trace.now_us(),
                    proc: self.proc,
                    shard: shard.0,
                    clock,
                });
                // Raise the floor on *every* table (the broadcast is
                // per-shard, covering all its partitions). Id order keeps
                // wakeup side effects deterministic under simulation.
                let mut handles: Vec<(TableId, Arc<ClientTable>)> =
                    self.tables.read().unwrap().iter().map(|(id, t)| (*id, t.clone())).collect();
                handles.sort_unstable_by_key(|(id, _)| id.0);
                for (_, t) in handles {
                    {
                        let mut st = t.state.lock().unwrap();
                        st.apply_min_clock(shard, clock);
                    }
                    t.cv.notify_all();
                }
            }
            Payload::VisibilityAck { table, batch_id } => {
                if let Ok(t) = self.table(table) {
                    let released = {
                        let mut st = t.state.lock().unwrap();
                        let r = st.apply_visibility_ack(batch_id);
                        if balance_checks() {
                            st.assert_balance("vis_ack");
                        }
                        r
                    };
                    if released {
                        t.cv.notify_all();
                    }
                    self.trace.record(|| Event::Visible {
                        at: self.trace.now_us(),
                        proc: self.proc,
                        table,
                        batch_id,
                    });
                }
            }
            Payload::ShardRecovered { shard, epoch } => self.on_shard_recovered(shard, epoch),
            Payload::AckProbe { table, origin, batch_id } => {
                // A recovered shard asks whether we saw this batch before
                // the crash (our ack may have died with it). Re-ack iff
                // applied; stay silent otherwise — the origin's
                // retransmission will produce a fresh push/ack cycle.
                if let (NodeId::Server(shard), Ok(t)) = (msg.src, self.table(table)) {
                    let applied =
                        t.state.lock().unwrap().already_applied(shard, origin, batch_id);
                    if applied {
                        let _ = self.net.send(Msg {
                            src: NodeId::Client(self.proc),
                            dst: msg.src,
                            payload: Payload::PushAck { table, origin, batch_id, by: self.proc },
                        });
                    }
                }
            }
            Payload::Shutdown => return false,
            // Clients never receive these:
            Payload::PushUpdates(_)
            | Payload::PullRow { .. }
            | Payload::ClockNotify { .. }
            | Payload::PushAck { .. }
            | Payload::Ping { .. }
            | Payload::Pong { .. } => {}
        }
        true
    }

    /// React to a shard's recovery announcement: adopt the new epoch,
    /// retransmit every sent-but-unechoed batch (the set the crash can
    /// have lost), re-promise our progress, and re-issue pulls that may
    /// have died with the old incarnation. Batches go out with their
    /// *original* clocks, so the shard's staleness bookkeeping sees the
    /// same history it would have without the crash; the server's
    /// per-origin dedup absorbs any batch that actually survived.
    fn on_shard_recovered(&self, shard: ShardId, epoch: u32) {
        self.shard_epochs[shard.0 as usize].fetch_max(epoch, Ordering::Relaxed);
        let mut handles: Vec<(TableId, Arc<ClientTable>)> =
            self.tables.read().unwrap().iter().map(|(id, t)| (*id, t.clone())).collect();
        handles.sort_unstable_by_key(|(id, _)| id.0);
        let mut pulls: Vec<(TableId, RowId, Clock)> = Vec::new();
        for (id, t) in &handles {
            // Epoch bump + retransmit under one lock acquisition: a flush
            // slipping between them would carry the new epoch with a
            // higher batch id and orphan the retransmissions behind the
            // server's per-origin watermark.
            let mut st = t.state.lock().unwrap();
            st.set_shard_epoch(shard, epoch);
            for batch in st.retransmit_batches(shard, epoch) {
                self.metrics.pushes_retransmitted.inc();
                let _ = self.net.send(Msg {
                    src: NodeId::Client(self.proc),
                    dst: NodeId::Server(shard),
                    payload: Payload::PushUpdates(batch),
                });
            }
            for (row, needed) in st.pulls_on_shard(shard) {
                pulls.push((*id, row, needed));
            }
        }
        // The progress promise goes out *after* the retransmissions on
        // this link, so "all updates stamped ≤ m precede it" still holds.
        let m = self.min_clock();
        let _ = self.net.send(Msg {
            src: NodeId::Client(self.proc),
            dst: NodeId::Server(shard),
            payload: Payload::ClockNotify { proc: self.proc, clock: m, epoch },
        });
        for (table, row, needed_clock) in pulls {
            self.metrics.pulls.inc();
            self.metrics.pull_retries.inc();
            let _ = self.net.send(Msg {
                src: NodeId::Client(self.proc),
                dst: NodeId::Server(shard),
                payload: Payload::PullRow {
                    table,
                    row,
                    needed_clock,
                    worker: WorkerId(u32::MAX),
                    trace: self.next_pull_ctx(),
                },
            });
        }
    }

    /// Flusher loop: periodically drain eager tables until stopped.
    pub fn run_flusher(self: &Arc<Self>) {
        let interval = Duration::from_micros(self.cfg.flush_interval_us.max(1));
        while !self.stop.load(Ordering::Relaxed) {
            self.flush_eager_tables();
            std::thread::sleep(interval);
        }
        // Final drain so no update is stranded at shutdown.
        let _ = self.flush_all_tables();
    }

    /// Ask background loops to stop (flusher notices the flag; ingress is
    /// stopped by a `Shutdown` message from the coordinator).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}
