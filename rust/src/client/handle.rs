//! The worker-facing API: [`WorkerCtx`] (one per application thread) and
//! [`TableHandle`] (cheap per-table accessor).
//!
//! This is the paper's application interface (§4.1):
//! `Get(table, row, col)`, `Inc(table, row, col, delta)` and `Clock()`,
//! plus row-granular variants the apps use for efficiency.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;
use crate::table::{RowId, TableId};
use crate::trace::Event;
use crate::types::{Clock, ProcId, WorkerId};

use super::core::ClientCore;

/// Per-worker-thread context handed to the application closure by
/// [`crate::coordinator::PsSystem::run_workers`].
pub struct WorkerCtx {
    worker: WorkerId,
    core: Arc<ClientCore>,
    /// The worker's clock, shared with its table handles.
    clock: Arc<AtomicU32>,
    /// Worker-local update sequence (trace only).
    seq: std::cell::Cell<u64>,
    /// Straggler slowdown multiplier for this worker (1.0 = none).
    slowdown: f64,
    /// Total number of workers `P` in the system.
    num_workers: u32,
}

impl WorkerCtx {
    /// Construct a context (coordinator use).
    pub(crate) fn new(
        worker: WorkerId,
        core: Arc<ClientCore>,
        slowdown: f64,
        num_workers: u32,
    ) -> Self {
        core.register_worker(worker);
        WorkerCtx {
            worker,
            core,
            clock: Arc::new(AtomicU32::new(0)),
            seq: std::cell::Cell::new(0),
            slowdown,
            num_workers,
        }
    }

    /// This worker's global id.
    pub fn worker_id(&self) -> WorkerId {
        self.worker
    }

    /// The hosting client process.
    pub fn proc_id(&self) -> ProcId {
        self.core.proc
    }

    /// Total workers `P` across all processes.
    pub fn num_workers(&self) -> u32 {
        self.num_workers
    }

    /// The worker's current clock.
    pub fn clock_value(&self) -> Clock {
        self.clock.load(Ordering::Relaxed)
    }

    /// A handle for one table (cheap; may be created per loop iteration).
    pub fn table(&self, id: TableId) -> TableHandle {
        TableHandle {
            id,
            core: self.core.clone(),
            worker: self.worker,
            clock: self.clock.clone(),
            seq: self.seq.clone(),
        }
    }

    /// `Clock()`: advance this worker's clock by one (paper §4.1). Flushes
    /// pending updates (the sync phase for BSP/SSP tables) and notifies
    /// servers when the process frontier moves.
    pub fn clock(&self) -> Result<Clock> {
        let c = self.core.clock(self.worker)?;
        self.clock.store(c, Ordering::Relaxed);
        Ok(c)
    }

    /// Simulate `base` seconds of compute, scaled by this worker's
    /// straggler slowdown (benches use this to inject stragglers).
    pub fn straggle(&self, base: Duration) {
        if self.slowdown > 0.0 {
            std::thread::sleep(base.mul_f64(self.slowdown));
        }
    }

    /// Is this worker configured as a straggler?
    pub fn is_straggler(&self) -> bool {
        self.slowdown > 1.0
    }

    /// Aggregate worker metrics of the hosting process.
    pub fn metrics(&self) -> Arc<crate::metrics::WorkerMetrics> {
        self.core.metrics.clone()
    }
}

/// Accessor for one table bound to one worker.
pub struct TableHandle {
    id: TableId,
    core: Arc<ClientCore>,
    worker: WorkerId,
    clock: Arc<AtomicU32>,
    seq: std::cell::Cell<u64>,
}

impl TableHandle {
    /// The table id.
    pub fn id(&self) -> TableId {
        self.id
    }

    /// `Get(table, row, col)` — clock-gated element read.
    pub fn get(&self, row: RowId, col: u32) -> Result<f32> {
        self.core.get(self.id, row, col, self.clock.load(Ordering::Relaxed))
    }

    /// Row-granular read (densified).
    pub fn get_row(&self, row: RowId) -> Result<Vec<f32>> {
        self.core.get_row(self.id, row, self.clock.load(Ordering::Relaxed))
    }

    /// Allocation-free row read into a caller buffer (hot loops).
    pub fn get_row_into(&self, row: RowId, out: &mut [f32]) -> Result<()> {
        self.core.get_row_into(self.id, row, out, self.clock.load(Ordering::Relaxed))
    }

    /// `Inc(table, row, col, delta)` — value-gated increment.
    pub fn inc(&self, row: RowId, col: u32, delta: f32) -> Result<()> {
        if self.core.trace.enabled() {
            let s = self.seq.get();
            self.seq.set(s + 1);
            let (worker, table) = (self.worker, self.id);
            self.core.trace.record(|| Event::Inc {
                at: self.core.trace.now_us(),
                worker,
                table,
                row,
                col,
                delta,
                seq: s + 1,
            });
        }
        self.core.inc(self.id, row, col, delta, self.worker)
    }

    /// Row-granular increment (dense delta vector).
    pub fn inc_row(&self, row: RowId, deltas: &[f32]) -> Result<()> {
        self.core.inc_row(self.id, row, deltas, self.worker)
    }

    /// Bulk increment: a batch of `(row, col, delta)` updates applied
    /// under one lock acquisition (write-back flush of a thread-local
    /// buffer — the paper's thread-cache discipline).
    pub fn inc_many(&self, updates: &[(RowId, u32, f32)]) -> Result<()> {
        self.core.inc_many(self.id, updates, self.worker)
    }
}

impl Clone for TableHandle {
    fn clone(&self) -> Self {
        TableHandle {
            id: self.id,
            core: self.core.clone(),
            worker: self.worker,
            clock: self.clock.clone(),
            seq: self.seq.clone(),
        }
    }
}
