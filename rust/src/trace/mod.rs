//! Event tracing.
//!
//! When enabled (`SystemConfig::trace`), the client library and server
//! shards record a timeline of update lifecycle events: generated →
//! pushed → applied-at-server → visible-everywhere, plus every blocking
//! episode with its reason. The trace is how the tests *prove* the
//! consistency invariants (e.g. Lemma 1's `|A_t|+|B_t| ≤ 2·v_thr·(P−1)`
//! and the Figure-1 VAP blocking schedule) rather than asserting them
//! indirectly, and how `benches/consistency.rs -- fig1` regenerates the
//! paper's Figure 1.

use std::sync::Mutex;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use crate::table::{RowId, TableId};
use crate::types::{Clock, ProcId, WorkerId};

/// Why a worker blocked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockReason {
    /// Read gate: cached row staleness exceeded the clock bound (CAP/SSP).
    Staleness,
    /// Write gate: accumulated unsynchronized magnitude would exceed
    /// `v_thr` (VAP).
    ValueBound,
}

/// One trace event.
#[derive(Debug, Clone)]
pub enum Event {
    /// A worker generated an update (Fig 1's `(seq, value)` pairs).
    Inc {
        /// When.
        at: Instant,
        /// Generating worker.
        worker: WorkerId,
        /// Table.
        table: TableId,
        /// Row.
        row: RowId,
        /// Column.
        col: u32,
        /// Delta value.
        delta: f32,
        /// Worker-local update sequence number.
        seq: u64,
    },
    /// A batch left a client process for a shard.
    Push {
        /// When.
        at: Instant,
        /// Origin process.
        proc: ProcId,
        /// Table.
        table: TableId,
        /// Batch id.
        batch_id: u64,
        /// Number of row-deltas inside.
        rows: usize,
    },
    /// The server reported a batch visible to all processes.
    Visible {
        /// When.
        at: Instant,
        /// Origin process.
        proc: ProcId,
        /// Table.
        table: TableId,
        /// Batch id.
        batch_id: u64,
    },
    /// A worker started blocking.
    BlockStart {
        /// When.
        at: Instant,
        /// Blocked worker.
        worker: WorkerId,
        /// Table.
        table: TableId,
        /// Why.
        reason: BlockReason,
    },
    /// The blocked worker resumed.
    BlockEnd {
        /// When.
        at: Instant,
        /// Worker.
        worker: WorkerId,
        /// Table.
        table: TableId,
        /// Why it had blocked.
        reason: BlockReason,
    },
    /// A client process applied a server push (origin's batch).
    Applied {
        /// When.
        at: Instant,
        /// Applying process.
        proc: ProcId,
        /// Table.
        table: TableId,
        /// Batch origin.
        origin: ProcId,
        /// Batch id.
        batch_id: u64,
        /// Push's min_clock.
        min_clock: Clock,
    },
    /// A client process raised a shard's freshness floor.
    Floor {
        /// When.
        at: Instant,
        /// Process.
        proc: ProcId,
        /// Shard.
        shard: u32,
        /// New floor.
        clock: Clock,
    },
    /// A shard applied a client push batch.
    ShardApplied {
        /// When.
        at: Instant,
        /// Shard.
        shard: u32,
        /// Origin proc.
        origin: ProcId,
        /// Batch id.
        batch_id: u64,
        /// Rows inside.
        rows: usize,
    },
    /// A shard broadcast a new min-clock frontier.
    Broadcast {
        /// When.
        at: Instant,
        /// Shard.
        shard: u32,
        /// Frontier.
        clock: Clock,
    },
    /// A worker's clock ticked.
    ClockTick {
        /// When.
        at: Instant,
        /// Worker.
        worker: WorkerId,
        /// New clock value.
        clock: Clock,
    },
}

impl Event {
    /// Event timestamp.
    pub fn at(&self) -> Instant {
        match self {
            Event::Inc { at, .. }
            | Event::Push { at, .. }
            | Event::Visible { at, .. }
            | Event::BlockStart { at, .. }
            | Event::BlockEnd { at, .. }
            | Event::Applied { at, .. }
            | Event::Floor { at, .. }
            | Event::ShardApplied { at, .. }
            | Event::Broadcast { at, .. }
            | Event::ClockTick { at, .. } => *at,
        }
    }
}

/// Shared, append-only trace recorder. Disabled recorders are free
/// (a single atomic load on the hot path).
pub struct TraceRecorder {
    enabled: AtomicBool,
    events: Mutex<Vec<Event>>,
}

impl TraceRecorder {
    /// Create a recorder; `enabled=false` makes all records no-ops.
    pub fn new(enabled: bool) -> Self {
        TraceRecorder { enabled: AtomicBool::new(enabled), events: Mutex::new(Vec::new()) }
    }

    /// Is recording on?
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Append an event (no-op when disabled).
    pub fn record(&self, f: impl FnOnce() -> Event) {
        if self.enabled() {
            self.events.lock().unwrap().push(f());
        }
    }

    /// Snapshot all events in record order.
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// True when no events recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render a compact textual timeline (relative µs timestamps), the
    /// format the Fig-1 bench prints.
    pub fn render(&self) -> String {
        let evs = self.events();
        let t0 = evs.first().map(|e| e.at());
        let mut out = String::new();
        for e in &evs {
            let us = t0.map(|t0| e.at().duration_since(t0).as_micros()).unwrap_or(0);
            use std::fmt::Write;
            let _ = match e {
                Event::Inc { worker, table, row, col, delta, seq, .. } => writeln!(
                    out,
                    "{us:>8}us inc    w{} t{} r{} c{} delta={delta} seq={seq}",
                    worker.0, table.0, row.0, col
                ),
                Event::Push { proc, table, batch_id, rows, .. } => writeln!(
                    out,
                    "{us:>8}us push   p{} t{} batch={batch_id} rows={rows}",
                    proc.0, table.0
                ),
                Event::Visible { proc, table, batch_id, .. } => writeln!(
                    out,
                    "{us:>8}us visib  p{} t{} batch={batch_id}",
                    proc.0, table.0
                ),
                Event::BlockStart { worker, table, reason, .. } => writeln!(
                    out,
                    "{us:>8}us block  w{} t{} {:?}",
                    worker.0, table.0, reason
                ),
                Event::BlockEnd { worker, table, reason, .. } => writeln!(
                    out,
                    "{us:>8}us unblk  w{} t{} {:?}",
                    worker.0, table.0, reason
                ),
                Event::ClockTick { worker, clock, .. } => {
                    writeln!(out, "{us:>8}us clock  w{} -> {clock}", worker.0)
                }
                Event::Applied { proc, table, origin, batch_id, min_clock, .. } => writeln!(
                    out,
                    "{us:>8}us apply  p{} t{} from p{} batch={batch_id} mclk={min_clock}",
                    proc.0, table.0, origin.0
                ),
                Event::Floor { proc, shard, clock, .. } => {
                    writeln!(out, "{us:>8}us floor  p{} shard{shard} -> {clock}", proc.0)
                }
                Event::ShardApplied { shard, origin, batch_id, rows, .. } => writeln!(
                    out,
                    "{us:>8}us s_appl shard{shard} from p{} batch={batch_id} rows={rows}",
                    origin.0
                ),
                Event::Broadcast { shard, clock, .. } => {
                    writeln!(out, "{us:>8}us bcast  shard{shard} min -> {clock}")
                }
            };
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_noop() {
        let r = TraceRecorder::new(false);
        r.record(|| Event::ClockTick { at: Instant::now(), worker: WorkerId(0), clock: 1 });
        assert!(r.is_empty());
    }

    #[test]
    fn enabled_recorder_collects_in_order() {
        let r = TraceRecorder::new(true);
        for i in 0..5 {
            r.record(|| Event::ClockTick { at: Instant::now(), worker: WorkerId(0), clock: i });
        }
        assert_eq!(r.len(), 5);
        match r.events()[4] {
            Event::ClockTick { clock, .. } => assert_eq!(clock, 4),
            _ => panic!(),
        }
    }

    #[test]
    fn render_contains_key_fields() {
        let r = TraceRecorder::new(true);
        r.record(|| Event::Inc {
            at: Instant::now(),
            worker: WorkerId(3),
            table: TableId(1),
            row: RowId(2),
            col: 7,
            delta: 1.5,
            seq: 6,
        });
        r.record(|| Event::BlockStart {
            at: Instant::now(),
            worker: WorkerId(3),
            table: TableId(1),
            reason: BlockReason::ValueBound,
        });
        let s = r.render();
        assert!(s.contains("w3") && s.contains("seq=6") && s.contains("ValueBound"), "{s}");
    }
}
