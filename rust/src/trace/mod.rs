//! Causal tracing: always-on span recorder + legacy event timeline.
//!
//! Two recording surfaces share one clock and one exporter:
//!
//! * **Spans** — the always-on, low-overhead path. Each layer records
//!   closed `[t0, t1]` intervals keyed by a causal [`TraceCtx`] minted at
//!   batch-seal (or pull-issue) time and propagated through the
//!   `comm::msg` envelopes, so one update's life — batched → on the wire
//!   → applied → held → visible — stitches into a single span tree across
//!   client, shard, apply and visibility layers. The record path is
//!   lock-free: a per-node seqlock ring ([`SpanRing`]) written through a
//!   cheap [`SpanSink`] handle; a full ring overwrites the oldest span
//!   and bumps `trace_spans_dropped_total` in the metrics registry.
//!   Span durations also feed `trace_stage_us{stage=...}` histograms —
//!   the per-stage latency breakdown the consistency models trade
//!   against.
//! * **Events** — the original [`Event`] timeline (Fig-1 bench, VAP
//!   blocking-schedule tests). Off by default (`SystemConfig::trace`);
//!   kept as a thin adapter that encodes each event into a dedicated
//!   ring, preserving global record order and the textual
//!   [`TraceRecorder::render`] format.
//!
//! Timestamps come from a [`TraceClock`] — wall time in production,
//! the sim's shared virtual-time cell under the deterministic harness —
//! so a simulated run's exported Chrome/Perfetto JSON
//! ([`TraceRecorder::trace_json`]) is byte-identical per seed and the
//! sim oracles can assert span-tree completeness.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::metrics::{Counter, Histogram, Registry};
use crate::table::{RowId, TableId};
use crate::types::{Clock, ProcId, ShardId, WorkerId};

/// Default span-ring capacity per node (slots). Sized so the sim sweeps,
/// the Fig-1 bench and the serve bench all fit without a single drop;
/// production overrides via `SystemConfig::trace_ring_slots`.
pub const DEFAULT_RING_SLOTS: usize = 8192;

/// Where trace timestamps come from: a wall anchor (production) or the
/// sim scheduler's shared virtual-time cell (determinism). Mirrors the
/// metrics registry's time injection so spans and metric histograms agree
/// under the sim.
#[derive(Clone)]
pub enum TraceClock {
    /// Wall time, microseconds since the anchor.
    Wall(Instant),
    /// Virtual time: reads the cell the sim scheduler advances.
    Virtual(Arc<AtomicU64>),
}

impl TraceClock {
    /// A wall clock anchored now.
    pub fn wall() -> Self {
        TraceClock::Wall(Instant::now())
    }

    /// Microseconds since the anchor / virtual time zero. Only
    /// differences are meaningful.
    pub fn now_us(&self) -> u64 {
        match self {
            TraceClock::Wall(t0) => t0.elapsed().as_micros() as u64,
            TraceClock::Virtual(c) => c.load(Ordering::Relaxed),
        }
    }
}

/// Compact causal trace context carried inside message envelopes
/// (16 bytes on the wire): the trace id minted at batch-seal / pull-issue
/// time plus the mint timestamp, which anchors the receiver's `net` span
/// without any clock exchange.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    /// Causal identity (0 = untraced).
    pub id: u64,
    /// Mint time (µs on the sender's trace clock).
    pub at_us: u64,
}

impl TraceCtx {
    /// The untraced context (id 0); receivers skip span recording for it.
    pub const NONE: TraceCtx = TraceCtx { id: 0, at_us: 0 };

    /// Is this the untraced context?
    pub fn is_none(&self) -> bool {
        self.id == 0
    }

    /// Mint a deterministic id from a lifecycle tag and identity words
    /// (FNV-1a; forced nonzero). Push batches use
    /// `(origin, batch_id, table)` — globally unique because each origin
    /// runs one batch-id counter across shards.
    pub fn mint(tag: u64, a: u64, b: u64, c: u64, at_us: u64) -> TraceCtx {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for w in [tag, a, b, c] {
            for byte in w.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        TraceCtx { id: h.max(1), at_us }
    }
}

/// Lifecycle stage a span covers. Discriminants are the wire/ring
/// encoding; values ≥ 100 encode legacy [`Event`] variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Client egress: first unsent update → batch sealed.
    Batch = 1,
    /// In flight: batch sealed/sent → accepted by the shard.
    Net = 2,
    /// Shard apply: WAL appended → store mutated.
    Apply = 3,
    /// Visibility gate: admission denied → released (strong VAP).
    Held = 4,
    /// Fan-out: forwarded to all procs → final ack (globally visible).
    Visible = 5,
    /// Pull round trip: request issued → reply installed.
    Pull = 6,
}

impl SpanKind {
    fn from_code(code: u64) -> Option<SpanKind> {
        Some(match code {
            1 => SpanKind::Batch,
            2 => SpanKind::Net,
            3 => SpanKind::Apply,
            4 => SpanKind::Held,
            5 => SpanKind::Visible,
            6 => SpanKind::Pull,
            _ => return None,
        })
    }

    /// Stage label used by `trace_stage_us` and the Perfetto export.
    pub fn stage(&self) -> &'static str {
        match self {
            SpanKind::Batch => "batch",
            SpanKind::Net => "net",
            SpanKind::Apply => "apply",
            SpanKind::Held => "held",
            SpanKind::Visible => "visible",
            SpanKind::Pull => "pull",
        }
    }
}

const STAGES: [&str; 6] = ["batch", "net", "apply", "held", "visible", "pull"];

/// Which node a ring (and its Perfetto "process" lane) belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanNode {
    /// A client process.
    Client(ProcId),
    /// A server shard.
    Shard(ShardId),
    /// The legacy event timeline.
    Legacy,
}

impl SpanNode {
    fn pid(&self) -> u64 {
        match self {
            SpanNode::Legacy => 1,
            SpanNode::Client(p) => 100 + p.0 as u64,
            SpanNode::Shard(s) => 200 + s.0 as u64,
        }
    }

    fn name(&self) -> String {
        match self {
            SpanNode::Legacy => "events".into(),
            SpanNode::Client(p) => format!("client{}", p.0),
            SpanNode::Shard(s) => format!("shard{}", s.0),
        }
    }
}

/// One decoded ring record. For spans, `a/b/c` carry
/// `(table, origin, batch_id)` — the identity the sim's span-tree oracle
/// joins against its applied-batch mirror; `d` is kind-specific. Legacy
/// events use `kind ≥ 100` and pack their payload across all lanes.
#[derive(Debug, Clone, Copy)]
pub struct SpanRec {
    /// Ring claim number (global record order within the ring).
    pub seq: u64,
    /// [`SpanKind`] discriminant, or `100 + variant` for legacy events.
    pub kind: u64,
    /// Causal trace id (0 for legacy events).
    pub id: u64,
    /// Open timestamp (µs).
    pub t0: u64,
    /// Close timestamp (µs; `== t0` for instants).
    pub t1: u64,
    /// Lane a (spans: table id).
    pub a: u64,
    /// Lane b (spans: origin proc).
    pub b: u64,
    /// Lane c (spans: batch id).
    pub c: u64,
    /// Lane d (kind-specific).
    pub d: u64,
    /// Lane e (kind-specific).
    pub e: u64,
    /// Lane f (kind-specific).
    pub f: u64,
}

const SLOT_LANES: usize = 11; // seq, kind, id, t0, t1, a..f

struct Slot {
    /// Seqlock version: `2·wrap+1` while a writer owns the slot,
    /// `2·wrap+2` once its record is complete, 0 never written.
    ver: AtomicU64,
    lanes: [AtomicU64; SLOT_LANES],
}

/// Bounded per-node span ring: lock-free writes (one `fetch_add` claim +
/// plain stores under a seqlock version), drop-oldest on overflow.
/// Readers ([`SpanRing::collect`]) skip slots a concurrent writer owns —
/// exports run at quiescence, so in practice nothing is skipped.
pub struct SpanRing {
    cap: u64,
    head: AtomicU64,
    slots: Vec<Slot>,
}

impl SpanRing {
    fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        SpanRing {
            cap: cap as u64,
            head: AtomicU64::new(0),
            slots: (0..cap)
                .map(|_| Slot { ver: AtomicU64::new(0), lanes: Default::default() })
                .collect(),
        }
    }

    /// Record one entry; returns true when an older record was
    /// overwritten (the caller counts the drop).
    fn record(&self, kind: u64, id: u64, t0: u64, t1: u64, rest: [u64; 6]) -> bool {
        let n = self.head.fetch_add(1, Ordering::SeqCst);
        let wrap = n / self.cap;
        let slot = &self.slots[(n % self.cap) as usize];
        slot.ver.store(2 * wrap + 1, Ordering::SeqCst);
        let lanes = [n, kind, id, t0, t1, rest[0], rest[1], rest[2], rest[3], rest[4], rest[5]];
        for (cell, v) in slot.lanes.iter().zip(lanes) {
            cell.store(v, Ordering::SeqCst);
        }
        slot.ver.store(2 * wrap + 2, Ordering::SeqCst);
        n >= self.cap
    }

    /// Snapshot every completed record, sorted by claim order.
    fn collect(&self) -> Vec<SpanRec> {
        let mut out = Vec::new();
        for slot in &self.slots {
            let v1 = slot.ver.load(Ordering::SeqCst);
            if v1 == 0 || v1 % 2 == 1 {
                continue; // never written, or a writer owns it right now
            }
            let mut lanes = [0u64; SLOT_LANES];
            for (dst, cell) in lanes.iter_mut().zip(&slot.lanes) {
                *dst = cell.load(Ordering::SeqCst);
            }
            if slot.ver.load(Ordering::SeqCst) != v1 {
                continue; // torn: a writer reclaimed the slot mid-read
            }
            out.push(SpanRec {
                seq: lanes[0],
                kind: lanes[1],
                id: lanes[2],
                t0: lanes[3],
                t1: lanes[4],
                a: lanes[5],
                b: lanes[6],
                c: lanes[7],
                d: lanes[8],
                e: lanes[9],
                f: lanes[10],
            });
        }
        out.sort_by_key(|r| r.seq);
        out
    }

    /// Records written so far (monotone, including dropped ones).
    fn written(&self) -> u64 {
        self.head.load(Ordering::SeqCst)
    }
}

/// State shared by the recorder and every sink it hands out.
struct Shared {
    clock: TraceClock,
    /// Gates the legacy [`Event`] surface only.
    enabled: AtomicBool,
    /// Gates span recording (on by default — "always-on"; the serve
    /// bench flips it off to measure the recorder's overhead).
    span_capture: AtomicBool,
    ring_slots: usize,
    hub: Option<Arc<Registry>>,
    /// Per-stage `trace_stage_us` handles, registered lazily on the first
    /// span of that stage so the dead-metric lint stays meaningful.
    stage_us: [OnceLock<Arc<Histogram>>; STAGES.len()],
    dropped_metric: OnceLock<Arc<Counter>>,
    dropped: AtomicU64,
    /// Registration-ordered span rings (one per node; the export's lane
    /// order, deterministic because nodes register in construction order).
    rings: Mutex<Vec<(SpanNode, Arc<SpanRing>)>>,
    /// The legacy event ring (global claim order = record order).
    legacy: Arc<SpanRing>,
}

impl Shared {
    fn note_drop(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
        if let Some(hub) = &self.hub {
            self.dropped_metric
                .get_or_init(|| {
                    hub.counter(
                        "trace_spans_dropped_total",
                        "spans overwritten by ring-buffer overflow",
                        &[],
                    )
                })
                .inc();
        }
    }

    fn note_stage(&self, kind: SpanKind, dur_us: u64) {
        if let Some(hub) = &self.hub {
            let idx = STAGES.iter().position(|s| *s == kind.stage()).unwrap();
            self.stage_us[idx]
                .get_or_init(|| {
                    hub.histogram(
                        "trace_stage_us",
                        "update-lifecycle stage latency from the span recorder",
                        &[("stage", kind.stage())],
                    )
                })
                .record(dur_us);
        }
    }
}

/// Lock-free per-node recording handle: an `Arc` pair (ring + shared
/// state). Cheap to clone; one per client core / server shard.
#[derive(Clone)]
pub struct SpanSink {
    shared: Arc<Shared>,
    ring: Arc<SpanRing>,
}

impl SpanSink {
    /// Current trace time (µs).
    pub fn now_us(&self) -> u64 {
        self.shared.clock.now_us()
    }

    /// Is span capture on?
    pub fn capturing(&self) -> bool {
        self.shared.span_capture.load(Ordering::Relaxed)
    }

    /// Record a closed span. `key` is `[table, origin, batch_id, extra]`:
    /// the first three are the causal identity every lifecycle span
    /// carries (the sim oracle's join key); `extra` is kind-specific.
    pub fn span(&self, kind: SpanKind, id: u64, t0: u64, t1: u64, key: [u64; 4]) {
        if !self.capturing() {
            return;
        }
        if self.ring.record(kind as u64, id, t0, t1, [key[0], key[1], key[2], key[3], 0, 0]) {
            self.shared.note_drop();
        }
        self.shared.note_stage(kind, t1.saturating_sub(t0));
    }
}

/// The trace recorder: owns the clock, the per-node span rings and the
/// legacy event ring. Shared as `Arc<TraceRecorder>` across every layer.
pub struct TraceRecorder {
    shared: Arc<Shared>,
}

impl TraceRecorder {
    /// A wall-clock recorder with default ring size and no metric hub
    /// (tests, benches). `enabled` gates the legacy event surface.
    pub fn new(enabled: bool) -> Self {
        Self::build(enabled, None, TraceClock::wall(), DEFAULT_RING_SLOTS)
    }

    /// Full constructor: metric hub for the stage histograms + drop
    /// counter, an injected clock (virtual under the sim), ring capacity.
    pub fn with_registry(
        enabled: bool,
        hub: Arc<Registry>,
        clock: TraceClock,
        ring_slots: usize,
    ) -> Self {
        Self::build(enabled, Some(hub), clock, ring_slots)
    }

    fn build(
        enabled: bool,
        hub: Option<Arc<Registry>>,
        clock: TraceClock,
        ring_slots: usize,
    ) -> Self {
        TraceRecorder {
            shared: Arc::new(Shared {
                clock,
                enabled: AtomicBool::new(enabled),
                span_capture: AtomicBool::new(true),
                ring_slots,
                hub,
                stage_us: Default::default(),
                dropped_metric: OnceLock::new(),
                dropped: AtomicU64::new(0),
                rings: Mutex::new(Vec::new()),
                legacy: Arc::new(SpanRing::new(ring_slots)),
            }),
        }
    }

    /// Is legacy event recording on?
    pub fn enabled(&self) -> bool {
        self.shared.enabled.load(Ordering::Relaxed)
    }

    /// Turn span capture on/off (the serve bench's overhead A/B switch).
    pub fn set_span_capture(&self, on: bool) {
        self.shared.span_capture.store(on, Ordering::Relaxed);
    }

    /// Current trace time (µs since the clock anchor).
    pub fn now_us(&self) -> u64 {
        self.shared.clock.now_us()
    }

    /// Spans overwritten by ring overflow so far.
    pub fn dropped_spans(&self) -> u64 {
        self.shared.dropped.load(Ordering::Relaxed)
    }

    /// A recording handle for `node`. One ring per node: repeat calls
    /// (e.g. a shard respawning after a crash) reuse the existing ring.
    pub fn sink(&self, node: SpanNode) -> SpanSink {
        let mut rings = self.shared.rings.lock().unwrap();
        let ring = match rings.iter().find(|(n, _)| *n == node) {
            Some((_, r)) => r.clone(),
            None => {
                let r = Arc::new(SpanRing::new(self.shared.ring_slots));
                rings.push((node, r.clone()));
                r
            }
        };
        SpanSink { shared: self.shared.clone(), ring }
    }

    /// Snapshot every node's spans (registration order, each ring in
    /// claim order). Legacy events are not included.
    pub fn spans(&self) -> Vec<(SpanNode, Vec<SpanRec>)> {
        let rings = self.shared.rings.lock().unwrap();
        rings
            .iter()
            .map(|(node, ring)| {
                (*node, ring.collect().into_iter().filter(|r| r.kind < 100).collect())
            })
            .collect()
    }

    /// ---- legacy event surface (Fig-1 bench, VAP schedule tests) ----

    /// Append an event (no-op when disabled). Events land in their own
    /// ring; global record order is the ring's claim order.
    pub fn record(&self, f: impl FnOnce() -> Event) {
        if !self.enabled() {
            return;
        }
        let (kind, lanes) = f().encode();
        let rest = [lanes[1], lanes[2], lanes[3], lanes[4], lanes[5], lanes[6]];
        if self.shared.legacy.record(kind, 0, lanes[0], lanes[0], rest) {
            self.shared.note_drop();
        }
    }

    /// Snapshot all events in record order.
    pub fn events(&self) -> Vec<Event> {
        self.shared.legacy.collect().iter().filter_map(Event::decode).collect()
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.shared.legacy.written().min(self.shared.legacy.cap) as usize
    }

    /// True when no events recorded.
    pub fn is_empty(&self) -> bool {
        self.shared.legacy.written() == 0
    }

    /// Render a compact textual timeline (relative µs timestamps), the
    /// format the Fig-1 bench prints.
    pub fn render(&self) -> String {
        let evs = self.events();
        let t0 = evs.first().map(|e| e.at());
        let mut out = String::new();
        for e in &evs {
            let us = t0.map(|t0| e.at().saturating_sub(t0)).unwrap_or(0);
            use std::fmt::Write;
            let _ = match e {
                Event::Inc { worker, table, row, col, delta, seq, .. } => writeln!(
                    out,
                    "{us:>8}us inc    w{} t{} r{} c{} delta={delta} seq={seq}",
                    worker.0, table.0, row.0, col
                ),
                Event::Push { proc, table, batch_id, rows, .. } => writeln!(
                    out,
                    "{us:>8}us push   p{} t{} batch={batch_id} rows={rows}",
                    proc.0, table.0
                ),
                Event::Visible { proc, table, batch_id, .. } => writeln!(
                    out,
                    "{us:>8}us visib  p{} t{} batch={batch_id}",
                    proc.0, table.0
                ),
                Event::BlockStart { worker, table, reason, .. } => writeln!(
                    out,
                    "{us:>8}us block  w{} t{} {:?}",
                    worker.0, table.0, reason
                ),
                Event::BlockEnd { worker, table, reason, .. } => writeln!(
                    out,
                    "{us:>8}us unblk  w{} t{} {:?}",
                    worker.0, table.0, reason
                ),
                Event::ClockTick { worker, clock, .. } => {
                    writeln!(out, "{us:>8}us clock  w{} -> {clock}", worker.0)
                }
                Event::Applied { proc, table, origin, batch_id, min_clock, .. } => writeln!(
                    out,
                    "{us:>8}us apply  p{} t{} from p{} batch={batch_id} mclk={min_clock}",
                    proc.0, table.0, origin.0
                ),
                Event::Floor { proc, shard, clock, .. } => {
                    writeln!(out, "{us:>8}us floor  p{} shard{shard} -> {clock}", proc.0)
                }
                Event::ShardApplied { shard, origin, batch_id, rows, .. } => writeln!(
                    out,
                    "{us:>8}us s_appl shard{shard} from p{} batch={batch_id} rows={rows}",
                    origin.0
                ),
                Event::Broadcast { shard, clock, .. } => {
                    writeln!(out, "{us:>8}us bcast  shard{shard} min -> {clock}")
                }
            };
        }
        out
    }

    /// ---- export ----

    /// Chrome/Perfetto trace-event JSON: spans as complete (`"X"`)
    /// events, legacy events as instants (`"i"`), one "process" lane per
    /// node. All-integer timestamps from the injected clock, fixed field
    /// order, stable sort — under the sim the output is a byte-identical
    /// function of `(config, seed)`.
    pub fn trace_json(&self) -> String {
        let rings = self.shared.rings.lock().unwrap();
        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        let mut push = |s: String, out: &mut String| {
            if !std::mem::take(&mut first) {
                out.push(',');
            }
            out.push('\n');
            out.push_str(&s);
        };
        let mut lanes: Vec<(SpanNode, Vec<SpanRec>)> =
            rings.iter().map(|(n, r)| (*n, r.collect())).collect();
        drop(rings);
        if self.shared.legacy.written() > 0 {
            lanes.push((SpanNode::Legacy, self.shared.legacy.collect()));
        }
        for (node, _) in &lanes {
            push(
                format!(
                    "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\"args\":{{\"name\":\"{}\"}}}}",
                    node.pid(),
                    node.name()
                ),
                &mut out,
            );
        }
        // (t0, lane registration order, claim order) — total and stable.
        let mut recs: Vec<(u64, usize, SpanRec)> = Vec::new();
        for (lane_idx, (_, rs)) in lanes.iter().enumerate() {
            for r in rs {
                recs.push((r.t0, lane_idx, *r));
            }
        }
        recs.sort_by_key(|(t0, lane, r)| (*t0, *lane, r.seq));
        for (_, lane_idx, r) in &recs {
            let pid = lanes[*lane_idx].0.pid();
            match SpanKind::from_code(r.kind) {
                Some(kind) => push(
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"span\",\"ph\":\"X\",\"pid\":{pid},\"tid\":0,\
                         \"ts\":{},\"dur\":{},\"args\":{{\"trace\":\"{:016x}\",\"table\":{},\
                         \"origin\":{},\"batch\":{},\"extra\":{}}}}}",
                        kind.stage(),
                        r.t0,
                        r.t1.saturating_sub(r.t0),
                        r.id,
                        r.a,
                        r.b,
                        r.c,
                        r.d
                    ),
                    &mut out,
                ),
                None => {
                    let name = Event::decode(r).map_or("event", |e| e.short_name());
                    push(
                        format!(
                            "{{\"name\":\"{name}\",\"cat\":\"event\",\"ph\":\"i\",\"pid\":{pid},\
                             \"tid\":0,\"ts\":{},\"s\":\"p\"}}",
                            r.t0
                        ),
                        &mut out,
                    );
                }
            }
        }
        out.push_str("\n]}\n");
        out
    }
}

/// Why a worker blocked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockReason {
    /// Read gate: cached row staleness exceeded the clock bound (CAP/SSP).
    Staleness,
    /// Write gate: accumulated unsynchronized magnitude would exceed
    /// `v_thr` (VAP).
    ValueBound,
}

/// One trace event. Timestamps are µs on the recorder's [`TraceClock`]
/// (virtual under the sim; relative wall µs in production).
#[derive(Debug, Clone)]
pub enum Event {
    /// A worker generated an update (Fig 1's `(seq, value)` pairs).
    Inc {
        /// When (µs).
        at: u64,
        /// Generating worker.
        worker: WorkerId,
        /// Table.
        table: TableId,
        /// Row.
        row: RowId,
        /// Column.
        col: u32,
        /// Delta value.
        delta: f32,
        /// Worker-local update sequence number.
        seq: u64,
    },
    /// A batch left a client process for a shard.
    Push {
        /// When (µs).
        at: u64,
        /// Origin process.
        proc: ProcId,
        /// Table.
        table: TableId,
        /// Batch id.
        batch_id: u64,
        /// Number of row-deltas inside.
        rows: usize,
    },
    /// The server reported a batch visible to all processes.
    Visible {
        /// When (µs).
        at: u64,
        /// Origin process.
        proc: ProcId,
        /// Table.
        table: TableId,
        /// Batch id.
        batch_id: u64,
    },
    /// A worker started blocking.
    BlockStart {
        /// When (µs).
        at: u64,
        /// Blocked worker.
        worker: WorkerId,
        /// Table.
        table: TableId,
        /// Why.
        reason: BlockReason,
    },
    /// The blocked worker resumed.
    BlockEnd {
        /// When (µs).
        at: u64,
        /// Worker.
        worker: WorkerId,
        /// Table.
        table: TableId,
        /// Why it had blocked.
        reason: BlockReason,
    },
    /// A client process applied a server push (origin's batch).
    Applied {
        /// When (µs).
        at: u64,
        /// Applying process.
        proc: ProcId,
        /// Table.
        table: TableId,
        /// Batch origin.
        origin: ProcId,
        /// Batch id.
        batch_id: u64,
        /// Push's min_clock.
        min_clock: Clock,
    },
    /// A client process raised a shard's freshness floor.
    Floor {
        /// When (µs).
        at: u64,
        /// Process.
        proc: ProcId,
        /// Shard.
        shard: u32,
        /// New floor.
        clock: Clock,
    },
    /// A shard applied a client push batch.
    ShardApplied {
        /// When (µs).
        at: u64,
        /// Shard.
        shard: u32,
        /// Origin proc.
        origin: ProcId,
        /// Batch id.
        batch_id: u64,
        /// Rows inside.
        rows: usize,
    },
    /// A shard broadcast a new min-clock frontier.
    Broadcast {
        /// When (µs).
        at: u64,
        /// Shard.
        shard: u32,
        /// Frontier.
        clock: Clock,
    },
    /// A worker's clock ticked.
    ClockTick {
        /// When (µs).
        at: u64,
        /// Worker.
        worker: WorkerId,
        /// New clock value.
        clock: Clock,
    },
}

impl Event {
    /// Event timestamp (µs on the recorder's clock).
    pub fn at(&self) -> u64 {
        match self {
            Event::Inc { at, .. }
            | Event::Push { at, .. }
            | Event::Visible { at, .. }
            | Event::BlockStart { at, .. }
            | Event::BlockEnd { at, .. }
            | Event::Applied { at, .. }
            | Event::Floor { at, .. }
            | Event::ShardApplied { at, .. }
            | Event::Broadcast { at, .. }
            | Event::ClockTick { at, .. } => *at,
        }
    }

    fn short_name(&self) -> &'static str {
        match self {
            Event::Inc { .. } => "inc",
            Event::Push { .. } => "push",
            Event::Visible { .. } => "visible",
            Event::BlockStart { .. } => "block",
            Event::BlockEnd { .. } => "unblock",
            Event::Applied { .. } => "applied",
            Event::Floor { .. } => "floor",
            Event::ShardApplied { .. } => "shard_applied",
            Event::Broadcast { .. } => "broadcast",
            Event::ClockTick { .. } => "clock",
        }
    }

    /// Ring encoding: `(kind ≥ 100, [at, lane a..f])`.
    fn encode(&self) -> (u64, [u64; 7]) {
        match *self {
            Event::Inc { at, worker, table, row, col, delta, seq } => (
                100,
                [
                    at,
                    worker.0 as u64,
                    table.0 as u64,
                    row.0,
                    col as u64,
                    delta.to_bits() as u64,
                    seq,
                ],
            ),
            Event::Push { at, proc, table, batch_id, rows } => {
                (101, [at, proc.0 as u64, table.0 as u64, batch_id, rows as u64, 0, 0])
            }
            Event::Visible { at, proc, table, batch_id } => {
                (102, [at, proc.0 as u64, table.0 as u64, batch_id, 0, 0, 0])
            }
            Event::BlockStart { at, worker, table, reason } => (
                103,
                [
                    at,
                    worker.0 as u64,
                    table.0 as u64,
                    (reason == BlockReason::ValueBound) as u64,
                    0,
                    0,
                    0,
                ],
            ),
            Event::BlockEnd { at, worker, table, reason } => (
                104,
                [
                    at,
                    worker.0 as u64,
                    table.0 as u64,
                    (reason == BlockReason::ValueBound) as u64,
                    0,
                    0,
                    0,
                ],
            ),
            Event::Applied { at, proc, table, origin, batch_id, min_clock } => (
                105,
                [at, proc.0 as u64, table.0 as u64, origin.0 as u64, batch_id, min_clock as u64, 0],
            ),
            Event::Floor { at, proc, shard, clock } => {
                (106, [at, proc.0 as u64, shard as u64, clock as u64, 0, 0, 0])
            }
            Event::ShardApplied { at, shard, origin, batch_id, rows } => {
                (107, [at, shard as u64, origin.0 as u64, batch_id, rows as u64, 0, 0])
            }
            Event::Broadcast { at, shard, clock } => {
                (108, [at, shard as u64, clock as u64, 0, 0, 0, 0])
            }
            Event::ClockTick { at, worker, clock } => {
                (109, [at, worker.0 as u64, clock as u64, 0, 0, 0, 0])
            }
        }
    }

    fn decode(r: &SpanRec) -> Option<Event> {
        let reason = |v: u64| if v == 1 { BlockReason::ValueBound } else { BlockReason::Staleness };
        Some(match r.kind {
            100 => Event::Inc {
                at: r.t0,
                worker: WorkerId(r.a as u32),
                table: TableId(r.b as u32),
                row: RowId(r.c),
                col: r.d as u32,
                delta: f32::from_bits(r.e as u32),
                seq: r.f,
            },
            101 => Event::Push {
                at: r.t0,
                proc: ProcId(r.a as u32),
                table: TableId(r.b as u32),
                batch_id: r.c,
                rows: r.d as usize,
            },
            102 => Event::Visible {
                at: r.t0,
                proc: ProcId(r.a as u32),
                table: TableId(r.b as u32),
                batch_id: r.c,
            },
            103 => Event::BlockStart {
                at: r.t0,
                worker: WorkerId(r.a as u32),
                table: TableId(r.b as u32),
                reason: reason(r.c),
            },
            104 => Event::BlockEnd {
                at: r.t0,
                worker: WorkerId(r.a as u32),
                table: TableId(r.b as u32),
                reason: reason(r.c),
            },
            105 => Event::Applied {
                at: r.t0,
                proc: ProcId(r.a as u32),
                table: TableId(r.b as u32),
                origin: ProcId(r.c as u32),
                batch_id: r.d,
                min_clock: r.e as Clock,
            },
            106 => Event::Floor {
                at: r.t0,
                proc: ProcId(r.a as u32),
                shard: r.b as u32,
                clock: r.c as Clock,
            },
            107 => Event::ShardApplied {
                at: r.t0,
                shard: r.a as u32,
                origin: ProcId(r.b as u32),
                batch_id: r.c,
                rows: r.d as usize,
            },
            108 => Event::Broadcast { at: r.t0, shard: r.a as u32, clock: r.b as Clock },
            109 => Event::ClockTick {
                at: r.t0,
                worker: WorkerId(r.a as u32),
                clock: r.b as Clock,
            },
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_noop() {
        let r = TraceRecorder::new(false);
        r.record(|| Event::ClockTick { at: 0, worker: WorkerId(0), clock: 1 });
        assert!(r.is_empty());
    }

    #[test]
    fn enabled_recorder_collects_in_order() {
        let r = TraceRecorder::new(true);
        for i in 0..5 {
            r.record(|| Event::ClockTick { at: r.now_us(), worker: WorkerId(0), clock: i });
        }
        assert_eq!(r.len(), 5);
        match r.events()[4] {
            Event::ClockTick { clock, .. } => assert_eq!(clock, 4),
            _ => panic!(),
        }
    }

    #[test]
    fn render_contains_key_fields() {
        let r = TraceRecorder::new(true);
        r.record(|| Event::Inc {
            at: r.now_us(),
            worker: WorkerId(3),
            table: TableId(1),
            row: RowId(2),
            col: 7,
            delta: 1.5,
            seq: 6,
        });
        r.record(|| Event::BlockStart {
            at: r.now_us(),
            worker: WorkerId(3),
            table: TableId(1),
            reason: BlockReason::ValueBound,
        });
        let s = r.render();
        assert!(s.contains("w3") && s.contains("seq=6") && s.contains("ValueBound"), "{s}");
    }

    #[test]
    fn event_encode_decode_roundtrip() {
        let r = TraceRecorder::new(true);
        r.record(|| Event::Applied {
            at: 42,
            proc: ProcId(1),
            table: TableId(2),
            origin: ProcId(3),
            batch_id: 99,
            min_clock: 7,
        });
        r.record(|| Event::Inc {
            at: 43,
            worker: WorkerId(5),
            table: TableId(0),
            row: RowId(11),
            col: 2,
            delta: -0.25,
            seq: 8,
        });
        let evs = r.events();
        match &evs[0] {
            Event::Applied { at, proc, table, origin, batch_id, min_clock } => {
                assert_eq!(
                    (*at, proc.0, table.0, origin.0, *batch_id, *min_clock),
                    (42, 1, 2, 3, 99, 7)
                );
            }
            other => panic!("wrong decode: {other:?}"),
        }
        match &evs[1] {
            Event::Inc { delta, seq, row, .. } => {
                assert_eq!((*delta, *seq, row.0), (-0.25, 8, 11));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn mint_is_deterministic_and_nonzero() {
        let a = TraceCtx::mint(1, 2, 3, 4, 100);
        let b = TraceCtx::mint(1, 2, 3, 4, 200);
        assert_eq!(a.id, b.id, "id depends only on identity words");
        assert_ne!(a.at_us, b.at_us);
        assert_ne!(a.id, 0);
        assert_ne!(a.id, TraceCtx::mint(1, 2, 3, 5, 100).id);
        assert!(TraceCtx::NONE.is_none() && !a.is_none());
    }

    #[test]
    fn span_ring_drops_oldest_and_counts() {
        let clock = Arc::new(AtomicU64::new(0));
        let hub = Arc::new(Registry::new());
        let r = TraceRecorder::with_registry(false, hub.clone(), TraceClock::Virtual(clock), 4);
        let sink = r.sink(SpanNode::Shard(ShardId(0)));
        for i in 0..6u64 {
            sink.span(SpanKind::Apply, i + 1, i, i + 10, [0, 0, i, 0]);
        }
        assert_eq!(r.dropped_spans(), 2);
        assert_eq!(hub.snapshot().counter_sum("trace_spans_dropped_total"), 2);
        let spans = r.spans();
        assert_eq!(spans.len(), 1);
        let recs = &spans[0].1;
        assert_eq!(recs.len(), 4, "ring keeps the newest cap records");
        assert_eq!(recs.first().unwrap().c, 2, "oldest two were overwritten");
        assert_eq!(hub.snapshot().hist_count("trace_stage_us"), 6, "stage hist sees every span");
    }

    #[test]
    fn span_capture_switch_stops_recording() {
        let r = TraceRecorder::new(false);
        let sink = r.sink(SpanNode::Client(ProcId(0)));
        sink.span(SpanKind::Batch, 1, 0, 5, [0, 0, 0, 0]);
        r.set_span_capture(false);
        sink.span(SpanKind::Batch, 2, 5, 9, [0, 0, 1, 0]);
        r.set_span_capture(true);
        assert_eq!(r.spans()[0].1.len(), 1);
    }

    #[test]
    fn sink_reuses_ring_per_node() {
        let r = TraceRecorder::new(false);
        let a = r.sink(SpanNode::Shard(ShardId(1)));
        a.span(SpanKind::Net, 1, 0, 1, [0, 0, 0, 0]);
        let b = r.sink(SpanNode::Shard(ShardId(1)));
        b.span(SpanKind::Net, 2, 1, 2, [0, 0, 1, 0]);
        let spans = r.spans();
        assert_eq!(spans.len(), 1, "respawned shard reuses its lane");
        assert_eq!(spans[0].1.len(), 2);
    }

    #[test]
    fn trace_json_is_deterministic_and_integer_only() {
        let mk = || {
            let clock = Arc::new(AtomicU64::new(0));
            let r = TraceRecorder::with_registry(
                true,
                Arc::new(Registry::new()),
                TraceClock::Virtual(clock.clone()),
                64,
            );
            let shard = r.sink(SpanNode::Shard(ShardId(0)));
            let client = r.sink(SpanNode::Client(ProcId(0)));
            clock.store(10, Ordering::Relaxed);
            client.span(SpanKind::Batch, 7, 2, 10, [0, 0, 3, 0]);
            clock.store(25, Ordering::Relaxed);
            shard.span(SpanKind::Net, 7, 10, 25, [0, 0, 3, 0]);
            r.record(|| Event::Broadcast { at: 30, shard: 0, clock: 2 });
            r.trace_json()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a, b, "same schedule, byte-identical export");
        assert!(a.contains("\"ph\":\"X\"") && a.contains("\"ph\":\"i\""), "{a}");
        assert!(a.contains("\"name\":\"net\"") && a.contains("\"dur\":15"), "{a}");
        assert!(a.contains("\"name\":\"shard0\"") && a.contains("\"name\":\"client0\""), "{a}");
        assert!(!a.contains('.'), "timestamps must be integers: {a}");
    }
}
