//! Data-parallel transformer-LM training through the parameter server —
//! the end-to-end validation workload (DESIGN.md E8): every layer of the
//! stack composes here (L1 Pallas matmul/attention kernels → L2 jax
//! fwd/bwd → AOT HLO artifact → Rust PJRT runtime → PS tables under a
//! bounded-asynchronous policy).
//!
//! The model lives in one PS table per parameter tensor; each worker
//! pulls the (boundedly stale) parameters, runs `transformer_step` on its
//! minibatch via [`crate::runtime::ComputePool`], and `Inc`s the scaled
//! negative gradients back. The model spec is read from
//! `artifacts/transformer_meta.txt`, which `python/compile/aot.py`
//! writes next to the HLO so the two sides can never drift.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::config::PolicyConfig;
use crate::coordinator::PsSystem;
use crate::error::{Error, Result};
use crate::runtime::{ComputePool, Tensor};
use crate::table::{RowId, RowKind, TableDesc, TableId};
use crate::util::Rng64;

/// First table id used for parameter tensors.
pub const PARAM_TABLE_BASE: u32 = 100;

/// Model spec exported by `aot.py` (shapes must match the artifact).
///
/// `transformer_meta.txt` format (whitespace-separated, `#` comments):
/// ```text
/// vocab 512
/// d_model 128
/// n_layers 2
/// n_heads 4
/// seq_len 64
/// batch 8
/// param embed 512 128
/// param L0.wq 128 128
/// ...
/// ```
#[derive(Debug, Clone)]
pub struct TransformerSpec {
    /// Vocabulary size.
    pub vocab: usize,
    /// Model width.
    pub d_model: usize,
    /// Number of layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Sequence length of the training step.
    pub seq_len: usize,
    /// Batch size baked into the artifact.
    pub batch: usize,
    /// Ordered parameter tensors: `(name, shape)`.
    pub params: Vec<(String, Vec<usize>)>,
}

impl TransformerSpec {
    /// Load the spec file written by `aot.py`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let path = artifacts_dir.as_ref().join("transformer_meta.txt");
        if !path.exists() {
            return Err(Error::MissingArtifact(path));
        }
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text)
    }

    /// Parse the meta text (separate from I/O for testability).
    pub fn parse(text: &str) -> Result<Self> {
        let mut vocab = None;
        let mut d_model = None;
        let mut n_layers = None;
        let mut n_heads = None;
        let mut seq_len = None;
        let mut batch = None;
        let mut params = Vec::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let key = it.next().unwrap();
            let bad =
                |what: &str| Error::Runtime(format!("meta line {}: bad {what}", no + 1));
            match key {
                "vocab" | "d_model" | "n_layers" | "n_heads" | "seq_len" | "batch" => {
                    let v: usize = it
                        .next()
                        .ok_or_else(|| bad(key))?
                        .parse()
                        .map_err(|_| bad(key))?;
                    match key {
                        "vocab" => vocab = Some(v),
                        "d_model" => d_model = Some(v),
                        "n_layers" => n_layers = Some(v),
                        "n_heads" => n_heads = Some(v),
                        "seq_len" => seq_len = Some(v),
                        _ => batch = Some(v),
                    }
                }
                "param" => {
                    let name = it.next().ok_or_else(|| bad("param name"))?.to_string();
                    let shape: Vec<usize> = it
                        .map(|d| d.parse().map_err(|_| bad("param dim")))
                        .collect::<Result<_>>()?;
                    if shape.is_empty() {
                        return Err(bad("param shape"));
                    }
                    params.push((name, shape));
                }
                _ => return Err(Error::Runtime(format!("meta line {}: unknown key {key}", no + 1))),
            }
        }
        let miss = |k: &str| Error::Runtime(format!("meta missing {k}"));
        Ok(TransformerSpec {
            vocab: vocab.ok_or_else(|| miss("vocab"))?,
            d_model: d_model.ok_or_else(|| miss("d_model"))?,
            n_layers: n_layers.ok_or_else(|| miss("n_layers"))?,
            n_heads: n_heads.ok_or_else(|| miss("n_heads"))?,
            seq_len: seq_len.ok_or_else(|| miss("seq_len"))?,
            batch: batch.ok_or_else(|| miss("batch"))?,
            params,
        })
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// `(num_rows, row_width)` layout of parameter `i`'s table: first dim
    /// = rows, remaining dims flattened into the row.
    pub fn table_layout(&self, i: usize) -> (u64, u32) {
        let shape = &self.params[i].1;
        match shape.len() {
            0 => (1, 1),
            1 => (1, shape[0] as u32),
            _ => (shape[0] as u64, shape[1..].iter().product::<usize>() as u32),
        }
    }
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer steps per worker.
    pub steps: usize,
    /// Learning rate.
    pub eta: f32,
    /// Consistency policy for all parameter tables.
    pub policy: PolicyConfig,
    /// RNG seed (init + data).
    pub seed: u64,
    /// Log the loss every `log_every` steps (0 = never).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 50,
            eta: 0.05,
            policy: PolicyConfig::Ssp { staleness: 1 },
            seed: 1234,
            log_every: 10,
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// Mean loss per step (averaged over workers).
    pub loss_curve: Vec<f64>,
    /// Steps/second aggregate.
    pub steps_per_sec: f64,
    /// Wall-clock seconds.
    pub wall_secs: f64,
    /// Parameter count.
    pub num_params: usize,
}

/// Create one PS table per parameter tensor.
pub fn create_param_tables(
    system: &PsSystem,
    spec: &TransformerSpec,
    policy: PolicyConfig,
) -> Result<()> {
    for i in 0..spec.params.len() {
        let (rows, width) = spec.table_layout(i);
        system.create_table(TableDesc {
            id: TableId(PARAM_TABLE_BASE + i as u32),
            num_rows: rows,
            row_width: width,
            row_kind: RowKind::Dense,
            policy,
        })?;
    }
    Ok(())
}

/// Synthetic token stream with learnable structure: a fixed random bigram
/// chain over the vocabulary (entropy well below uniform, so the LM loss
/// has headroom to drop).
pub struct BigramData {
    /// Per token: candidate successors.
    pub next: Vec<Vec<u32>>,
    vocab: usize,
}

impl BigramData {
    /// Build a bigram chain with `fanout` successors per token.
    pub fn new(vocab: usize, fanout: usize, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed);
        let next = (0..vocab)
            .map(|_| (0..fanout).map(|_| rng.below(vocab) as u32).collect())
            .collect();
        BigramData { next, vocab }
    }

    /// Sample a `[batch, seq+1]` token block (inputs + shifted targets).
    pub fn sample(&self, rng: &mut Rng64, batch: usize, seq: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let mut tok = rng.below(self.vocab) as u32;
            out.push(tok as f32);
            for _ in 0..seq {
                let succ = &self.next[tok as usize];
                tok = succ[rng.below(succ.len())];
                out.push(tok as f32);
            }
        }
        out
    }
}

fn read_params(ctx: &crate::client::WorkerCtx, spec: &TransformerSpec) -> Result<Vec<Tensor>> {
    let mut out = Vec::with_capacity(spec.params.len());
    for i in 0..spec.params.len() {
        let (rows, width) = spec.table_layout(i);
        let t = ctx.table(TableId(PARAM_TABLE_BASE + i as u32));
        let mut data = Vec::with_capacity(rows as usize * width as usize);
        for r in 0..rows {
            data.extend(t.get_row(RowId(r))?);
        }
        out.push(Tensor::new(data, spec.params[i].1.clone())?);
    }
    Ok(out)
}

fn apply_grads(
    ctx: &crate::client::WorkerCtx,
    spec: &TransformerSpec,
    grads: &[Tensor],
    eta: f32,
) -> Result<()> {
    for (i, g) in grads.iter().enumerate() {
        let (rows, width) = spec.table_layout(i);
        let t = ctx.table(TableId(PARAM_TABLE_BASE + i as u32));
        for r in 0..rows as usize {
            let chunk = &g.data[r * width as usize..(r + 1) * width as usize];
            let deltas: Vec<f32> = chunk.iter().map(|v| -eta * v).collect();
            t.inc_row(RowId(r as u64), &deltas)?;
        }
    }
    Ok(())
}

/// Train the transformer data-parallel across all workers. `pool` must
/// serve the `transformer_step` artifact.
pub fn train(
    system: &PsSystem,
    spec: Arc<TransformerSpec>,
    pool: Arc<ComputePool>,
    cfg: TrainConfig,
) -> Result<TrainResult> {
    create_param_tables(system, &spec, cfg.policy)?;
    let p = system.config().num_workers();
    let cfg = Arc::new(cfg);

    let t0 = Instant::now();
    let curves: Vec<Vec<f64>> = system.run_workers({
        let spec = spec.clone();
        let pool = pool.clone();
        let cfg = cfg.clone();
        move |ctx| {
            let mut rng = Rng64::seed_from_u64(cfg.seed ^ ((ctx.worker_id().0 as u64) << 17));
            // Worker 0 initializes parameters (scaled-normal init).
            if ctx.worker_id().0 == 0 {
                let mut init_rng = Rng64::seed_from_u64(cfg.seed);
                for i in 0..spec.params.len() {
                    let (rows, width) = spec.table_layout(i);
                    let std = init_std(&spec.params[i].0, spec.d_model);
                    let t = ctx.table(TableId(PARAM_TABLE_BASE + i as u32));
                    for r in 0..rows {
                        let vals: Vec<f32> =
                            (0..width).map(|_| std * init_rng.normal_f32()).collect();
                        t.inc_row(RowId(r), &vals).unwrap();
                    }
                }
            }
            ctx.clock().unwrap();
            let data = BigramData::new(spec.vocab, 4, cfg.seed + 1);
            let mut curve = Vec::with_capacity(cfg.steps);
            for step in 0..cfg.steps {
                let params = read_params(ctx, &spec).unwrap();
                let tokens = Tensor::new(
                    data.sample(&mut rng, spec.batch, spec.seq_len),
                    vec![spec.batch, spec.seq_len + 1],
                )
                .unwrap();
                let mut inputs = params;
                inputs.push(tokens);
                let outputs = pool.run("transformer_step", inputs).unwrap();
                let loss = outputs[0].item().unwrap() as f64;
                curve.push(loss);
                apply_grads(ctx, &spec, &outputs[1..], cfg.eta).unwrap();
                ctx.clock().unwrap();
                if cfg.log_every > 0 && step % cfg.log_every == 0 && ctx.worker_id().0 == 0 {
                    eprintln!("[worker0] step {step:>4} loss {loss:.4}");
                }
            }
            curve
        }
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let mut loss_curve = vec![0.0; cfg.steps];
    for c in &curves {
        for (i, v) in c.iter().enumerate() {
            loss_curve[i] += v / curves.len() as f64;
        }
    }
    Ok(TrainResult {
        loss_curve,
        steps_per_sec: (cfg.steps as u64 * p as u64) as f64 / wall.max(1e-9),
        wall_secs: wall,
        num_params: spec.num_params(),
    })
}

/// Initialization scale per parameter name (embedding vs projection vs
/// layernorm).
fn init_std(name: &str, d_model: usize) -> f32 {
    if name.contains("ln_") || name.ends_with("_scale") {
        0.0 // layernorm scales start at 0 delta from the baked-in 1.0
    } else if name.contains("embed") || name.contains("pos") {
        0.02
    } else {
        (1.0 / d_model as f32).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigram_data_is_learnable_structure() {
        let d = BigramData::new(64, 2, 3);
        let mut rng = Rng64::seed_from_u64(5);
        let block = d.sample(&mut rng, 4, 16);
        assert_eq!(block.len(), 4 * 17);
        for &t in &block {
            assert!(t >= 0.0 && t < 64.0);
        }
        // successors constrained: given token t, next ∈ next[t] (fanout 2)
        for b in 0..4 {
            for s in 0..16 {
                let cur = block[b * 17 + s] as usize;
                let nxt = block[b * 17 + s + 1] as u32;
                assert!(d.next[cur].contains(&nxt));
            }
        }
    }

    #[test]
    fn spec_parse_and_layout() {
        let text = "\
# comment
vocab 256
d_model 32
n_layers 1
n_heads 2
seq_len 8
batch 2
param embed 256 32
param ln_f_scale 32
param w1 32 4 32
";
        let spec = TransformerSpec::parse(text).unwrap();
        assert_eq!(spec.vocab, 256);
        assert_eq!(spec.table_layout(0), (256, 32));
        assert_eq!(spec.table_layout(1), (1, 32));
        assert_eq!(spec.table_layout(2), (32, 128));
        assert_eq!(spec.num_params(), 256 * 32 + 32 + 32 * 128);
    }

    #[test]
    fn spec_parse_rejects_incomplete_or_garbage() {
        assert!(TransformerSpec::parse("vocab 8\n").is_err());
        assert!(TransformerSpec::parse("wat 8\n").is_err());
        assert!(TransformerSpec::parse("vocab eight\n").is_err());
        assert!(TransformerSpec::parse("param x\n").is_err());
    }

    #[test]
    fn missing_meta_is_reported() {
        match TransformerSpec::load("/nowhere") {
            Err(Error::MissingArtifact(_)) => {}
            other => panic!("{other:?}"),
        }
    }
}
