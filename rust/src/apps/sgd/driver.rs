//! The distributed SGD driver.

use crate::util::Rng64;
use std::sync::Arc;
use std::time::Instant;

use crate::config::PolicyConfig;
use crate::consistency::cvap::theorem1_eta;
use crate::coordinator::PsSystem;
use crate::error::Result;
use crate::runtime::{ComputePool, Tensor};
use crate::table::{RowId, RowKind, TableDesc, TableId};

use super::data::LogRegData;

/// Table holding the weight vector (rows of `row_width` parameters).
pub const WEIGHT_TABLE: TableId = TableId(20);

/// Row width used to shard the weight vector across rows/shards.
const ROW_WIDTH: usize = 64;

/// SGD run configuration.
#[derive(Debug, Clone)]
pub struct SgdConfig {
    /// Iterations (clocks) per worker.
    pub iters: usize,
    /// Minibatch size per step.
    pub batch: usize,
    /// Consistency policy for the weight table.
    pub policy: PolicyConfig,
    /// Theorem-1 constants: Lipschitz bound `L` of the per-example loss.
    pub lipschitz: f64,
    /// Theorem-1 constants: diameter bound `F`.
    pub diameter: f64,
    /// Override learning rate (None ⇒ the Theorem-1 schedule
    /// `η_t = σ/√t` with `σ = F/(L√(v_thr·P))`, using `v_thr = 1` for
    /// policies without a value bound).
    pub eta: Option<f64>,
    /// Compute gradients through the `logreg_grad` AOT artifact.
    pub use_xla: bool,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig {
            iters: 100,
            batch: 32,
            policy: PolicyConfig::Vap { v_thr: 4.0, strong: false },
            lipschitz: 4.0,
            diameter: 4.0,
            eta: None,
            use_xla: false,
            seed: 17,
        }
    }
}

/// Result of a distributed SGD run.
#[derive(Debug, Clone)]
pub struct SgdResult {
    /// Final weights (synchronized view).
    pub weights: Vec<f32>,
    /// Full-dataset loss after training.
    pub final_loss: f64,
    /// Accuracy after training.
    pub accuracy: f64,
    /// Mean per-worker loss recorded at each iteration on the worker's
    /// *noisy view* — `f_t(x̃_t)` of the theory; the regret integrand.
    pub loss_curve: Vec<f64>,
    /// Wall-clock seconds.
    pub wall_secs: f64,
    /// Steps per second (aggregate).
    pub steps_per_sec: f64,
}

/// Number of weight rows for dimension `d`.
fn num_rows(d: usize) -> u64 {
    ((d + ROW_WIDTH - 1) / ROW_WIDTH) as u64
}

/// Create the weight table for dimension `d` under `policy`.
pub fn create_weight_table(system: &PsSystem, d: usize, policy: PolicyConfig) -> Result<()> {
    system.create_table(TableDesc {
        id: WEIGHT_TABLE,
        num_rows: num_rows(d),
        row_width: ROW_WIDTH as u32,
        row_kind: RowKind::Dense,
        policy,
    })
}

/// Read the full weight vector through a worker's table handle.
fn read_weights(t: &crate::client::TableHandle, d: usize) -> Result<Vec<f32>> {
    let mut w = Vec::with_capacity(num_rows(d) as usize * ROW_WIDTH);
    for r in 0..num_rows(d) {
        w.extend(t.get_row(RowId(r))?);
    }
    w.truncate(d);
    Ok(w)
}

/// Write a scaled gradient: `w ← w − η·g` via per-row `Inc`s.
fn apply_grad(t: &crate::client::TableHandle, g: &[f32], eta: f32) -> Result<()> {
    for (r, chunk) in g.chunks(ROW_WIDTH).enumerate() {
        let deltas: Vec<f32> = chunk.iter().map(|v| -eta * v).collect();
        t.inc_row(RowId(r as u64), &deltas)?;
    }
    Ok(())
}

/// Run distributed SGD on `data` (shared by all workers; each samples its
/// own minibatches from its shard).
pub fn run_sgd(
    system: &PsSystem,
    data: Arc<LogRegData>,
    cfg: SgdConfig,
    pool: Option<Arc<ComputePool>>,
) -> Result<SgdResult> {
    create_weight_table(system, data.d, cfg.policy)?;
    let p = system.config().num_workers();
    let v_thr = cfg.policy.v_thr().unwrap_or(1.0) as f64;
    let cfg = Arc::new(cfg);

    let t0 = Instant::now();
    let curves: Vec<Vec<f64>> = system.run_workers({
        let data = data.clone();
        let cfg = cfg.clone();
        move |ctx| {
            let t = ctx.table(WEIGHT_TABLE);
            let mut rng = Rng64::seed_from_u64(cfg.seed ^ ((ctx.worker_id().0 as u64) << 40));
            // Each worker draws from its contiguous data shard.
            let p = ctx.num_workers() as usize;
            let wid = ctx.worker_id().0 as usize;
            let shard = data.n() / p.max(1);
            let lo = wid * shard;
            let hi = if wid + 1 == p { data.n() } else { lo + shard };
            let mut curve = Vec::with_capacity(cfg.iters);
            for it in 1..=cfg.iters {
                let w = read_weights(&t, data.d).unwrap();
                let idx: Vec<usize> =
                    (0..cfg.batch).map(|_| rng.range(lo, hi.max(lo + 1))).collect();
                let g = if cfg.use_xla {
                    xla_grad(pool.as_ref().unwrap(), &data, &w, &idx).unwrap()
                } else {
                    data.grad(&w, &idx)
                };
                // minibatch loss on the noisy view (regret integrand)
                curve.push(minibatch_loss(&data, &w, &idx));
                let eta = cfg
                    .eta
                    .unwrap_or_else(|| {
                        theorem1_eta(it as u64, cfg.lipschitz, cfg.diameter, v_thr, p as u32)
                    }) as f32;
                apply_grad(&t, &g, eta).unwrap();
                ctx.clock().unwrap();
            }
            curve
        }
    })?;
    let wall = t0.elapsed().as_secs_f64();

    // Synchronized read of the final weights: ask one worker per proc to
    // spin until the pipeline drains (compare two consecutive reads).
    let weights = read_final_weights(system, &data)?;
    let final_loss = data.loss(&weights);
    let accuracy = data.accuracy(&weights);

    let iters = cfg.iters;
    let mut loss_curve = vec![0.0; iters];
    for c in &curves {
        for (i, v) in c.iter().enumerate() {
            loss_curve[i] += v / curves.len() as f64;
        }
    }
    Ok(SgdResult {
        weights,
        final_loss,
        accuracy,
        loss_curve,
        wall_secs: wall,
        steps_per_sec: (iters as u64 * p as u64) as f64 / wall.max(1e-9),
    })
}

fn minibatch_loss(data: &LogRegData, w: &[f32], idx: &[usize]) -> f64 {
    let mut total = 0.0;
    for &i in idx {
        let logit: f32 = data.xi(i).iter().zip(w).map(|(a, b)| a * b).sum();
        let z = logit as f64;
        let yi = data.y[i] as f64;
        let l = if z > 0.0 {
            z + (1.0 + (-z).exp()).ln() - yi * z
        } else {
            (1.0 + z.exp()).ln() - yi * z
        };
        total += l;
    }
    total / idx.len().max(1) as f64
}

/// Gradient through the `logreg_grad` artifact: inputs `w [D]`, `x [B,D]`,
/// `y [B]`; outputs `(grad [D], loss [])`.
fn xla_grad(
    pool: &ComputePool,
    data: &LogRegData,
    w: &[f32],
    idx: &[usize],
) -> Result<Vec<f32>> {
    let d = data.d;
    let b = idx.len();
    let mut xb = Vec::with_capacity(b * d);
    let mut yb = Vec::with_capacity(b);
    for &i in idx {
        xb.extend_from_slice(data.xi(i));
        yb.push(data.y[i]);
    }
    let out = pool.run(
        "logreg_grad",
        vec![
            Tensor::new(w.to_vec(), vec![d])?,
            Tensor::new(xb, vec![b, d])?,
            Tensor::new(yb, vec![b])?,
        ],
    )?;
    // The artifact returns the SUM gradient (padding-exact); normalize to
    // the mean to match the pure-Rust path.
    let mut g = out.into_iter().next().map(|t| t.data).unwrap_or_default();
    let inv = 1.0 / b.max(1) as f32;
    for v in &mut g {
        *v *= inv;
    }
    Ok(g)
}

/// Poll the weight table until two consecutive fully-synced reads agree
/// (the async pipeline has drained), then return the weights.
fn read_final_weights(system: &PsSystem, data: &LogRegData) -> Result<Vec<f32>> {
    let d = data.d;
    let out = system.run_workers(move |ctx| {
        if ctx.worker_id().0 != 0 {
            return Vec::new();
        }
        let t = ctx.table(WEIGHT_TABLE);
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        let mut prev = read_weights(&t, d).unwrap();
        loop {
            std::thread::sleep(std::time::Duration::from_millis(10));
            let cur = read_weights(&t, d).unwrap();
            if cur == prev || Instant::now() > deadline {
                return cur;
            }
            prev = cur;
        }
    })?;
    Ok(out.into_iter().next().unwrap_or_default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::sgd::data::LogRegDataConfig;
    use crate::config::SystemConfig;

    fn sys() -> PsSystem {
        PsSystem::launch(
            SystemConfig::builder()
                .num_server_shards(2)
                .num_client_procs(2)
                .threads_per_proc(1)
                .flush_interval_us(50)
                .build(),
        )
        .unwrap()
    }

    #[test]
    fn distributed_sgd_reduces_loss_under_vap() {
        let system = sys();
        let data = Arc::new(LogRegData::synthetic(&LogRegDataConfig {
            n: 2048,
            d: 32,
            noise: 0.02,
            seed: 21,
        }));
        let zero_loss = data.loss(&vec![0.0; data.d]);
        let res = run_sgd(
            &system,
            data.clone(),
            SgdConfig {
                iters: 60,
                batch: 32,
                policy: PolicyConfig::Vap { v_thr: 4.0, strong: false },
                eta: Some(0.25),
                ..SgdConfig::default()
            },
            None,
        )
        .unwrap();
        assert!(
            res.final_loss < zero_loss * 0.75,
            "loss {} should beat zero-weight loss {}",
            res.final_loss,
            zero_loss
        );
        assert!(res.accuracy > 0.8, "accuracy {}", res.accuracy);
        assert_eq!(res.loss_curve.len(), 60);
        system.shutdown().unwrap();
    }

    #[test]
    fn sgd_under_ssp_also_converges() {
        let system = sys();
        let data = Arc::new(LogRegData::synthetic(&LogRegDataConfig {
            n: 1024,
            d: 16,
            noise: 0.02,
            seed: 22,
        }));
        let res = run_sgd(
            &system,
            data.clone(),
            SgdConfig {
                iters: 40,
                batch: 32,
                policy: PolicyConfig::Ssp { staleness: 2 },
                eta: Some(0.25),
                ..SgdConfig::default()
            },
            None,
        )
        .unwrap();
        assert!(res.accuracy > 0.75, "accuracy {}", res.accuracy);
        system.shutdown().unwrap();
    }
}
