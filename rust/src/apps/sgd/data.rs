//! Synthetic logistic-regression data with a known ground-truth separator.

use crate::util::Rng64;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct LogRegDataConfig {
    /// Number of examples.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Label-noise rate (probability a label is flipped).
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for LogRegDataConfig {
    fn default() -> Self {
        LogRegDataConfig { n: 4096, d: 64, noise: 0.05, seed: 13 }
    }
}

/// A dense logistic-regression dataset: `x` is row-major `n×d`, labels in
/// `{0, 1}`, plus the planted true weight vector.
#[derive(Debug, Clone)]
pub struct LogRegData {
    /// Row-major features, `n × d`.
    pub x: Vec<f32>,
    /// Labels in `{0.0, 1.0}`.
    pub y: Vec<f32>,
    /// Feature dimension.
    pub d: usize,
    /// The planted separator (unit norm × 3).
    pub w_true: Vec<f32>,
}

impl LogRegData {
    /// Generate a dataset (deterministic per seed).
    pub fn synthetic(cfg: &LogRegDataConfig) -> Self {
        let mut rng = Rng64::seed_from_u64(cfg.seed);
        let mut w_true: Vec<f32> = (0..cfg.d).map(|_| rng.normal_f32()).collect();
        let norm = (w_true.iter().map(|v| v * v).sum::<f32>()).sqrt().max(1e-9);
        for w in &mut w_true {
            *w *= 3.0 / norm;
        }
        let mut x = Vec::with_capacity(cfg.n * cfg.d);
        let mut y = Vec::with_capacity(cfg.n);
        for _ in 0..cfg.n {
            let xi: Vec<f32> = (0..cfg.d).map(|_| rng.normal_f32()).collect();
            let logit: f32 = xi.iter().zip(&w_true).map(|(a, b)| a * b).sum();
            let p = 1.0 / (1.0 + (-logit).exp());
            let mut label = if rng.f32() < p { 1.0 } else { 0.0 };
            if rng.f64() < cfg.noise {
                label = 1.0 - label;
            }
            x.extend(xi);
            y.push(label);
        }
        LogRegData { x, y, d: cfg.d, w_true }
    }

    /// Number of examples.
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Example `i`'s feature slice.
    pub fn xi(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Mean logistic loss of weights `w` over the whole set.
    pub fn loss(&self, w: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for i in 0..self.n() {
            let logit: f32 = self.xi(i).iter().zip(w).map(|(a, b)| a * b).sum();
            let yi = self.y[i] as f64;
            let z = logit as f64;
            // numerically stable: log(1+e^z) - y z
            let l = if z > 0.0 { z + (1.0 + (-z).exp()).ln() - yi * z } else { (1.0 + z.exp()).ln() - yi * z };
            total += l;
        }
        total / self.n() as f64
    }

    /// Classification accuracy of weights `w`.
    pub fn accuracy(&self, w: &[f32]) -> f64 {
        let mut correct = 0usize;
        for i in 0..self.n() {
            let logit: f32 = self.xi(i).iter().zip(w).map(|(a, b)| a * b).sum();
            let pred = if logit > 0.0 { 1.0 } else { 0.0 };
            if pred == self.y[i] {
                correct += 1;
            }
        }
        correct as f64 / self.n() as f64
    }

    /// Minibatch logistic gradient at `w` over examples `idx`:
    /// `(1/B) Σ (σ(x·w) − y) x`. Pure-Rust reference path (the AOT
    /// artifact computes the same thing on the XLA side).
    pub fn grad(&self, w: &[f32], idx: &[usize]) -> Vec<f32> {
        let mut g = vec![0.0f32; self.d];
        for &i in idx {
            let xi = self.xi(i);
            let logit: f32 = xi.iter().zip(w).map(|(a, b)| a * b).sum();
            let p = 1.0 / (1.0 + (-logit).exp());
            let r = p - self.y[i];
            for (gj, xj) in g.iter_mut().zip(xi) {
                *gj += r * xj;
            }
        }
        let inv = 1.0 / idx.len().max(1) as f32;
        for gj in &mut g {
            *gj *= inv;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_separable() {
        let cfg = LogRegDataConfig { n: 512, d: 16, noise: 0.0, seed: 3 };
        let a = LogRegData::synthetic(&cfg);
        let b = LogRegData::synthetic(&cfg);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        // Labels are *sampled* from sigmoid(x·w), so even the planted
        // separator misclassifies near-boundary points; with ‖w‖ = 3 the
        // Bayes accuracy is ≈ 0.85.
        assert!(a.accuracy(&a.w_true) > 0.8, "acc={}", a.accuracy(&a.w_true));
    }

    #[test]
    fn gradient_descends_loss() {
        let data = LogRegData::synthetic(&LogRegDataConfig {
            n: 1024,
            d: 8,
            noise: 0.02,
            seed: 5,
        });
        let mut w = vec![0.0f32; data.d];
        let idx: Vec<usize> = (0..data.n()).collect();
        let l0 = data.loss(&w);
        for _ in 0..50 {
            let g = data.grad(&w, &idx);
            for (wj, gj) in w.iter_mut().zip(&g) {
                *wj -= 0.5 * gj;
            }
        }
        let l1 = data.loss(&w);
        assert!(l1 < l0 * 0.7, "full-batch GD should reduce loss: {l0} -> {l1}");
        assert!(data.accuracy(&w) > 0.8);
    }

    #[test]
    fn grad_at_optimum_is_small() {
        // At the separator with clean labels the average gradient is small.
        let data = LogRegData::synthetic(&LogRegDataConfig {
            n: 2048,
            d: 8,
            noise: 0.0,
            seed: 9,
        });
        let idx: Vec<usize> = (0..data.n()).collect();
        let g = data.grad(&data.w_true, &idx);
        let norm: f32 = g.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm < 0.2, "grad norm at truth = {norm}");
    }

    #[test]
    fn loss_is_stable_for_large_logits() {
        let data = LogRegData::synthetic(&LogRegDataConfig::default());
        let big = vec![100.0f32; data.d];
        assert!(data.loss(&big).is_finite());
        let small = vec![-100.0f32; data.d];
        assert!(data.loss(&small).is_finite());
    }
}
