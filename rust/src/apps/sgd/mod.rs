//! Distributed SGD for (regularized) logistic / linear regression — the
//! workload of the paper's §3 theory: Theorem 1 proves `O(√T)` regret for
//! SGD under VAP with `η_t = σ/√t`, `σ = F/(L√(v_thr·P))`.
//!
//! The weight vector lives in a PS table (`row_width`-wide rows); each
//! worker owns a shard of the training set, reads the (possibly stale,
//! boundedly so) weights, computes a minibatch gradient — either in pure
//! Rust or through the `logreg_grad` JAX/Pallas artifact — and `Inc`s the
//! scaled negative gradient back. `benches/sgd_convergence.rs` measures
//! the regret and compares it against
//! [`crate::consistency::cvap::theorem1_regret_bound`].

mod data;
mod driver;

pub use data::{LogRegData, LogRegDataConfig};
pub use driver::{run_sgd, SgdConfig, SgdResult, WEIGHT_TABLE};
