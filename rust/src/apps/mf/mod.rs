//! Matrix factorization by distributed SGD — a second realistic PS
//! workload (collaborative filtering): find `L ∈ R^{m×k}`, `R ∈ R^{n×k}`
//! minimizing `Σ_(i,j)∈Ω (A_ij − L_i·R_j)²` over observed ratings `Ω`.
//!
//! Both factor matrices live in PS tables (one row per user/item), so —
//! unlike LDA where only counts are shared — *every* parameter is both
//! read and written on the hot path, giving the consistency models a
//! denser conflict pattern to referee.

use crate::util::Rng64;
use std::sync::Arc;
use std::time::Instant;

use crate::config::PolicyConfig;
use crate::coordinator::PsSystem;
use crate::error::Result;
use crate::table::{RowId, RowKind, TableDesc, TableId};

/// Left-factor (user) table id.
pub const L_TABLE: TableId = TableId(30);
/// Right-factor (item) table id.
pub const R_TABLE: TableId = TableId(31);

/// An observed-ratings dataset with planted low-rank structure.
#[derive(Debug, Clone)]
pub struct MfData {
    /// Observed entries `(i, j, value)`.
    pub ratings: Vec<(u32, u32, f32)>,
    /// Rows (users).
    pub m: usize,
    /// Columns (items).
    pub n: usize,
    /// Planted rank.
    pub rank: usize,
}

impl MfData {
    /// Generate `density·m·n` observations of a rank-`rank` matrix plus
    /// Gaussian noise (deterministic per seed).
    pub fn synthetic(m: usize, n: usize, rank: usize, density: f64, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed);
        let lt: Vec<f32> = (0..m * rank).map(|_| 2.0 * rng.f32() - 1.0).collect();
        let rt: Vec<f32> = (0..n * rank).map(|_| 2.0 * rng.f32() - 1.0).collect();
        let mut ratings = Vec::new();
        for i in 0..m {
            for j in 0..n {
                if rng.f64() < density {
                    let v: f32 = (0..rank).map(|f| lt[i * rank + f] * rt[j * rank + f]).sum();
                    ratings.push((i as u32, j as u32, v + 0.01 * (rng.f32() - 0.5)));
                }
            }
        }
        MfData { ratings, m, n, rank }
    }

    /// Root-mean-square error of factor matrices `l` (m×k) and `r` (n×k)
    /// over the observed entries.
    pub fn rmse(&self, l: &[f32], r: &[f32], k: usize) -> f64 {
        let mut se = 0.0f64;
        for &(i, j, v) in &self.ratings {
            let pred: f32 = (0..k)
                .map(|f| l[i as usize * k + f] * r[j as usize * k + f])
                .sum();
            se += ((pred - v) as f64).powi(2);
        }
        (se / self.ratings.len().max(1) as f64).sqrt()
    }
}

/// MF run configuration.
#[derive(Debug, Clone)]
pub struct MfConfig {
    /// Factorization rank `k`.
    pub rank: usize,
    /// SGD epochs (each = one clock).
    pub epochs: usize,
    /// Learning rate.
    pub eta: f32,
    /// L2 regularization.
    pub lambda: f32,
    /// Consistency policy for both factor tables.
    pub policy: PolicyConfig,
    /// RNG seed.
    pub seed: u64,
}

impl Default for MfConfig {
    fn default() -> Self {
        MfConfig {
            rank: 8,
            epochs: 10,
            eta: 0.05,
            lambda: 0.01,
            policy: PolicyConfig::Ssp { staleness: 1 },
            seed: 29,
        }
    }
}

/// MF run result.
#[derive(Debug, Clone)]
pub struct MfResult {
    /// RMSE over observed entries after training.
    pub rmse: f64,
    /// RMSE per epoch (convergence curve).
    pub rmse_curve: Vec<f64>,
    /// Observed ratings per second processed.
    pub ratings_per_sec: f64,
}

/// Run distributed MF: ratings partitioned round-robin over workers.
pub fn run_mf(system: &PsSystem, data: Arc<MfData>, cfg: MfConfig) -> Result<MfResult> {
    for (id, rows) in [(L_TABLE, data.m), (R_TABLE, data.n)] {
        system.create_table(TableDesc {
            id,
            num_rows: rows as u64,
            row_width: cfg.rank as u32,
            row_kind: RowKind::Dense,
            policy: cfg.policy,
        })?;
    }
    let cfg = Arc::new(cfg);
    let t0 = Instant::now();
    let total: u64 = data.ratings.len() as u64 * cfg.epochs as u64;

    let curves: Vec<Vec<f64>> = system.run_workers({
        let data = data.clone();
        let cfg = cfg.clone();
        move |ctx| {
            let k = cfg.rank;
            let lt = ctx.table(L_TABLE);
            let rt = ctx.table(R_TABLE);
            let p = ctx.num_workers() as usize;
            let wid = ctx.worker_id().0 as usize;
            let mine: Vec<usize> =
                (0..data.ratings.len()).filter(|i| i % p == wid).collect();
            let mut rng = Rng64::seed_from_u64(cfg.seed ^ ((wid as u64) << 33));

            // Random init (worker 0 seeds both tables to break symmetry).
            if wid == 0 {
                for i in 0..data.m {
                    let init: Vec<f32> =
                        (0..k).map(|_| 0.4 * (rng.f32() - 0.5)).collect();
                    lt.inc_row(RowId(i as u64), &init).unwrap();
                }
                for j in 0..data.n {
                    let init: Vec<f32> =
                        (0..k).map(|_| 0.4 * (rng.f32() - 0.5)).collect();
                    rt.inc_row(RowId(j as u64), &init).unwrap();
                }
            }
            ctx.clock().unwrap();

            let mut curve = Vec::with_capacity(cfg.epochs);
            for _epoch in 0..cfg.epochs {
                let mut se = 0.0f64;
                for &ri in &mine {
                    let (i, j, v) = data.ratings[ri];
                    let li = lt.get_row(RowId(i as u64)).unwrap();
                    let rj = rt.get_row(RowId(j as u64)).unwrap();
                    let pred: f32 = li.iter().zip(&rj).map(|(a, b)| a * b).sum();
                    let err = pred - v;
                    se += (err as f64).powi(2);
                    let dl: Vec<f32> = (0..k)
                        .map(|f| -cfg.eta * (err * rj[f] + cfg.lambda * li[f]))
                        .collect();
                    let dr: Vec<f32> = (0..k)
                        .map(|f| -cfg.eta * (err * li[f] + cfg.lambda * rj[f]))
                        .collect();
                    lt.inc_row(RowId(i as u64), &dl).unwrap();
                    rt.inc_row(RowId(j as u64), &dr).unwrap();
                }
                curve.push((se / mine.len().max(1) as f64).sqrt());
                ctx.clock().unwrap();
            }
            curve
        }
    })?;
    let wall = t0.elapsed().as_secs_f64();

    // Final synchronized factors: read after drain.
    let k = cfg.rank;
    let (m, n) = (data.m, data.n);
    let factors = system.run_workers(move |ctx| {
        if ctx.worker_id().0 != 0 {
            return (Vec::new(), Vec::new());
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
        let lt = ctx.table(L_TABLE);
        let rt = ctx.table(R_TABLE);
        let mut l = Vec::with_capacity(m * k);
        for i in 0..m {
            l.extend(lt.get_row(RowId(i as u64)).unwrap());
        }
        let mut r = Vec::with_capacity(n * k);
        for j in 0..n {
            r.extend(rt.get_row(RowId(j as u64)).unwrap());
        }
        (l, r)
    })?;
    let (l, r) = factors.into_iter().next().unwrap();
    let rmse = data.rmse(&l, &r, k);

    let mut rmse_curve = vec![0.0; cfg.epochs];
    for c in &curves {
        for (i, v) in c.iter().enumerate() {
            rmse_curve[i] += v / curves.len() as f64;
        }
    }
    Ok(MfResult { rmse, rmse_curve, ratings_per_sec: total as f64 / wall.max(1e-9) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn synthetic_data_shape() {
        let d = MfData::synthetic(20, 30, 4, 0.5, 1);
        assert!(!d.ratings.is_empty());
        assert!(d.ratings.len() < 20 * 30);
        for &(i, j, _) in &d.ratings {
            assert!((i as usize) < 20 && (j as usize) < 30);
        }
        // determinism
        let d2 = MfData::synthetic(20, 30, 4, 0.5, 1);
        assert_eq!(d.ratings, d2.ratings);
    }

    #[test]
    fn mf_reduces_rmse() {
        let system = PsSystem::launch(
            SystemConfig::builder()
                .num_server_shards(2)
                .num_client_procs(2)
                .threads_per_proc(1)
                .flush_interval_us(50)
                .build(),
        )
        .unwrap();
        let data = Arc::new(MfData::synthetic(40, 40, 3, 0.4, 11));
        let res = run_mf(
            &system,
            data.clone(),
            MfConfig { rank: 6, epochs: 15, eta: 0.1, ..MfConfig::default() },
        )
        .unwrap();
        assert!(
            res.rmse < res.rmse_curve[0] * 0.5,
            "rmse should halve: start {} end {}",
            res.rmse_curve[0],
            res.rmse
        );
        system.shutdown().unwrap();
    }
}
