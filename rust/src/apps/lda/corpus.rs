//! Synthetic 20News-scale corpus generation.
//!
//! The paper's Table 1 reports the 20News statistics:
//!
//! | | 20News |
//! |---|---|
//! | # of docs   | 11,269 |
//! | # of words  | 53,485 |
//! | # of tokens | 1,318,299 |
//!
//! We do not ship the actual 20News text; instead a seeded generator
//! produces a corpus with matched shape: the same document count, the
//! same vocabulary size, token count within a small tolerance, a Zipf
//! word marginal (natural-language-like) and genuine latent topic
//! structure (documents draw topic mixtures from a Dirichlet; topics
//! have distinct Zipf-permuted word distributions), so LDA has real
//! structure to recover. DESIGN.md §3 records this substitution.

use crate::util::Rng64;

/// Configuration of the synthetic corpus generator.
#[derive(Debug, Clone)]
pub struct SyntheticCorpusConfig {
    /// Number of documents (Table 1: 11,269).
    pub num_docs: usize,
    /// Vocabulary size (Table 1: 53,485).
    pub vocab: usize,
    /// Target total token count (Table 1: 1,318,299). Doc lengths are
    /// drawn around `tokens/num_docs` and the last doc absorbs rounding,
    /// so the total matches exactly.
    pub tokens: usize,
    /// Number of latent topics planted in the data.
    pub true_topics: usize,
    /// Dirichlet concentration of per-document topic mixtures.
    pub doc_alpha: f64,
    /// Zipf exponent of the word marginal (≈1 for natural language).
    pub zipf_s: f64,
    /// RNG seed.
    pub seed: u64,
}

impl SyntheticCorpusConfig {
    /// The full 20News-scale configuration (Table 1 statistics).
    pub fn news20() -> Self {
        SyntheticCorpusConfig {
            num_docs: 11_269,
            vocab: 53_485,
            tokens: 1_318_299,
            true_topics: 20,
            doc_alpha: 0.1,
            zipf_s: 1.05,
            seed: 20_131_231, // the paper's date
        }
    }

    /// A scaled-down corpus: same shape, `1/factor` of the docs/tokens and
    /// vocabulary (for CI-speed tests and the scaled benches).
    pub fn news20_scaled(factor: usize) -> Self {
        let f = factor.max(1);
        SyntheticCorpusConfig {
            num_docs: (11_269 / f).max(8),
            vocab: (53_485 / f).max(64),
            tokens: (1_318_299 / f).max(512),
            true_topics: 20.min((53_485 / f).max(2)),
            doc_alpha: 0.1,
            zipf_s: 1.05,
            seed: 20_131_231,
        }
    }
}

/// A bag-of-words corpus: `docs[d]` is the token list (word ids) of doc d.
#[derive(Debug, Clone)]
pub struct Corpus {
    /// Token lists per document.
    pub docs: Vec<Vec<u32>>,
    /// Vocabulary size.
    pub vocab: usize,
}

/// Summary statistics — the reproduction of Table 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorpusStats {
    /// Number of documents.
    pub num_docs: usize,
    /// Number of *distinct* words that actually occur.
    pub num_words: usize,
    /// Total token count.
    pub num_tokens: usize,
}

impl std::fmt::Display for CorpusStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "| {:<12} | {:>9} |", "", "20News")?;
        writeln!(f, "|--------------|-----------|")?;
        writeln!(f, "| # of docs    | {:>9} |", self.num_docs)?;
        writeln!(f, "| # of words   | {:>9} |", self.num_words)?;
        write!(f, "| # of tokens  | {:>9} |", self.num_tokens)
    }
}

/// Zipf sampler over `n` ranks with exponent `s` (inverse-CDF on a
/// precomputed cumulative table — exact, O(log n) per draw).
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut Rng64) -> usize {
        let u: f64 = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}


impl Corpus {
    /// Generate a corpus from the config (deterministic per seed).
    pub fn synthetic(cfg: &SyntheticCorpusConfig) -> Corpus {
        let mut rng = Rng64::seed_from_u64(cfg.seed);
        let k = cfg.true_topics.max(1);
        let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);

        // Each topic is the Zipf marginal under a topic-specific
        // pseudo-random permutation of the vocabulary (cheap, heavy-tailed,
        // and distinct across topics).
        let topic_perm_seed: Vec<u64> = (0..k).map(|_| rng.next_u64()).collect();
        let permute = |topic: usize, word: usize, vocab: usize| -> u32 {
            // Feistel-ish mix: deterministic permutation-ish mapping;
            // collisions are fine (they just merge probability mass).
            let mut z = (word as u64) ^ topic_perm_seed[topic];
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            (z % vocab as u64) as u32
        };

        // Doc lengths: mean tokens/docs, ±50% uniform; final doc absorbs
        // the remainder so the total is exact.
        let mean_len = (cfg.tokens / cfg.num_docs).max(1);
        let mut remaining = cfg.tokens;
        let mut docs = Vec::with_capacity(cfg.num_docs);
        for d in 0..cfg.num_docs {
            let len = if d + 1 == cfg.num_docs {
                remaining
            } else {
                let lo = mean_len / 2;
                let hi = mean_len + mean_len / 2;
                let len = rng.range(lo.max(1), hi.max(1) + 1);
                len.min(remaining.saturating_sub(cfg.num_docs - d - 1))
            };
            remaining -= len;
            let theta = rng.dirichlet(k, cfg.doc_alpha);
            // cumulative for topic draws
            let mut cum = theta.clone();
            for i in 1..k {
                cum[i] += cum[i - 1];
            }
            let mut toks = Vec::with_capacity(len);
            for _ in 0..len {
                let u: f64 = rng.f64();
                let t = cum.iter().position(|&c| c >= u).unwrap_or(k - 1);
                let rank = zipf.sample(&mut rng);
                toks.push(permute(t, rank, cfg.vocab));
            }
            docs.push(toks);
        }
        Corpus { docs, vocab: cfg.vocab }
    }

    /// Compute the Table-1 statistics of this corpus.
    pub fn stats(&self) -> CorpusStats {
        let mut seen = vec![false; self.vocab];
        let mut tokens = 0usize;
        for d in &self.docs {
            tokens += d.len();
            for &w in d {
                seen[w as usize] = true;
            }
        }
        CorpusStats {
            num_docs: self.docs.len(),
            num_words: seen.iter().filter(|&&s| s).count(),
            num_tokens: tokens,
        }
    }

    /// Partition document indices round-robin over `p` workers (the
    /// strong-scaling experiment's layout).
    pub fn partition(&self, p: usize) -> Vec<Vec<usize>> {
        let mut parts = vec![Vec::new(); p.max(1)];
        for d in 0..self.docs.len() {
            parts[d % p.max(1)].push(d);
        }
        parts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_corpus_matches_requested_shape() {
        let cfg = SyntheticCorpusConfig::news20_scaled(100);
        let c = Corpus::synthetic(&cfg);
        let s = c.stats();
        assert_eq!(s.num_docs, cfg.num_docs);
        assert_eq!(s.num_tokens, cfg.tokens, "token total must be exact");
        assert!(s.num_words <= cfg.vocab);
        assert!(s.num_words > cfg.vocab / 10, "vocabulary barely used: {}", s.num_words);
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = SyntheticCorpusConfig::news20_scaled(200);
        let a = Corpus::synthetic(&cfg);
        let b = Corpus::synthetic(&cfg);
        assert_eq!(a.docs, b.docs);
        let mut cfg2 = cfg.clone();
        cfg2.seed += 1;
        let c = Corpus::synthetic(&cfg2);
        assert_ne!(a.docs, c.docs);
    }

    #[test]
    fn word_marginal_is_heavy_tailed() {
        let cfg = SyntheticCorpusConfig::news20_scaled(50);
        let c = Corpus::synthetic(&cfg);
        let mut counts = vec![0usize; c.vocab];
        for d in &c.docs {
            for &w in d {
                counts[w as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = counts.iter().sum();
        let top1pct: usize = counts.iter().take(counts.len() / 100 + 1).sum();
        assert!(
            top1pct as f64 > total as f64 * 0.05,
            "top 1% of words should carry ≥5% of mass (Zipf), got {top1pct}/{total}"
        );
    }

    #[test]
    fn partition_covers_all_docs_disjointly() {
        let cfg = SyntheticCorpusConfig::news20_scaled(400);
        let c = Corpus::synthetic(&cfg);
        let parts = c.partition(4);
        let mut seen = vec![false; c.docs.len()];
        for p in &parts {
            for &d in p {
                assert!(!seen[d], "doc {d} assigned twice");
                seen[d] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        let max = parts.iter().map(|p| p.len()).max().unwrap();
        let min = parts.iter().map(|p| p.len()).min().unwrap();
        assert!(max - min <= 1, "round-robin must balance");
    }

    #[test]
    fn table1_stats_render() {
        let s = CorpusStats { num_docs: 11_269, num_words: 53_485, num_tokens: 1_318_299 };
        let out = s.to_string();
        assert!(out.contains("11269") && out.contains("53485") && out.contains("1318299"));
    }
}
