//! Collapsed Gibbs sampling for LDA over the parameter server.
//!
//! Each worker owns a document partition; one `Clock()` = one full sweep
//! over the partition (the paper's iteration unit). The shared word-topic
//! and topic-sum tables are accessed through the consistency-gated
//! `Get`/`Inc` API, so the sampler sees exactly the (bounded) staleness
//! the table's policy allows — which is the entire point of the paper's
//! evaluation: throughput vs. convergence across consistency models.

use crate::util::Rng64;
use std::sync::Arc;
use std::time::Instant;

use crate::client::WorkerCtx;
use crate::config::PolicyConfig;
use crate::coordinator::PsSystem;
use crate::error::Result;
use crate::runtime::{ComputePool, Tensor};
use crate::table::{RowId, RowKind, TableDesc, TableId};

use super::corpus::Corpus;

/// Table ids used by the LDA app.
pub const WORD_TOPIC_TABLE: TableId = TableId(10);
/// Topic-sum table id.
pub const TOPIC_SUM_TABLE: TableId = TableId(11);

/// LDA run configuration.
#[derive(Debug, Clone)]
pub struct LdaConfig {
    /// Number of topics `K` (the paper fixes 2000; scaled runs use less).
    pub num_topics: usize,
    /// Dirichlet prior on doc-topic mixtures.
    pub alpha: f32,
    /// Dirichlet prior on topic-word distributions.
    pub beta: f32,
    /// Gibbs sweeps (each sweep = one clock).
    pub sweeps: usize,
    /// Consistency policy for the shared tables (the paper's §5 uses weak
    /// VAP; benches sweep this).
    pub policy: PolicyConfig,
    /// RNG seed.
    pub seed: u64,
    /// Compute topic probabilities through the JAX/Pallas artifact
    /// (`lda_topic_probs`) instead of the pure-Rust inner loop.
    pub use_xla: bool,
}

impl Default for LdaConfig {
    fn default() -> Self {
        LdaConfig {
            num_topics: 50,
            alpha: 0.1,
            beta: 0.01,
            sweeps: 5,
            policy: PolicyConfig::Vap { v_thr: 8.0, strong: false },
            seed: 7,
            use_xla: false,
        }
    }
}

/// Result of an LDA run.
#[derive(Debug, Clone)]
pub struct GibbsResult {
    /// Tokens processed per second, summed over workers.
    pub tokens_per_sec: f64,
    /// Wall-clock seconds of the sampling phase.
    pub wall_secs: f64,
    /// Total tokens × sweeps processed.
    pub tokens_processed: u64,
    /// Mean per-sweep log-likelihood proxy (mean log p of sampled topic),
    /// one entry per sweep — rising values = convergence.
    pub loglik_curve: Vec<f64>,
}

/// Create the LDA tables on `system` for the given vocabulary/topics.
pub fn create_tables(system: &PsSystem, vocab: usize, cfg: &LdaConfig) -> Result<()> {
    system.create_table(TableDesc {
        id: WORD_TOPIC_TABLE,
        num_rows: vocab as u64,
        row_width: cfg.num_topics as u32,
        row_kind: RowKind::Dense,
        policy: cfg.policy,
    })?;
    system.create_table(TableDesc {
        id: TOPIC_SUM_TABLE,
        num_rows: 1,
        row_width: cfg.num_topics as u32,
        row_kind: RowKind::Dense,
        policy: cfg.policy,
    })?;
    Ok(())
}

/// Run distributed LDA: one worker per system worker thread, documents
/// partitioned round-robin. Returns aggregate throughput + convergence.
pub fn run_lda(
    system: &PsSystem,
    corpus: Arc<Corpus>,
    cfg: LdaConfig,
    pool: Option<Arc<ComputePool>>,
) -> Result<GibbsResult> {
    create_tables(system, corpus.vocab, &cfg)?;
    let p = system.config().num_workers() as usize;
    let parts = Arc::new(corpus.partition(p));
    let cfg = Arc::new(cfg);

    let t0 = Instant::now();
    let per_worker: Vec<(u64, Vec<f64>)> = system.run_workers({
        let corpus = corpus.clone();
        let parts = parts.clone();
        let cfg = cfg.clone();
        move |ctx| {
            let my_docs = &parts[ctx.worker_id().0 as usize];
            sample_partition(ctx, &corpus, my_docs, &cfg, pool.clone())
        }
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let tokens: u64 = per_worker.iter().map(|(t, _)| t).sum();
    let sweeps = cfg.sweeps;
    let mut loglik_curve = vec![0.0f64; sweeps];
    for (_, curve) in &per_worker {
        for (i, v) in curve.iter().enumerate() {
            loglik_curve[i] += v / per_worker.len() as f64;
        }
    }
    Ok(GibbsResult {
        tokens_per_sec: tokens as f64 / wall.max(1e-9),
        wall_secs: wall,
        tokens_processed: tokens,
        loglik_curve,
    })
}

/// One worker's sampling loop over its documents. Returns (tokens
/// processed, per-sweep log-lik proxy).
fn sample_partition(
    ctx: &mut WorkerCtx,
    corpus: &Corpus,
    my_docs: &[usize],
    cfg: &LdaConfig,
    pool: Option<Arc<ComputePool>>,
) -> (u64, Vec<f64>) {
    let k = cfg.num_topics;
    let wt = ctx.table(WORD_TOPIC_TABLE);
    let ts = ctx.table(TOPIC_SUM_TABLE);
    let mut rng = Rng64::seed_from_u64(cfg.seed ^ ((ctx.worker_id().0 as u64) << 32));

    // Local state: doc-topic counts + assignments.
    let mut n_dk: Vec<Vec<f32>> = my_docs.iter().map(|_| vec![0.0; k]).collect();
    let mut z: Vec<Vec<u16>> = my_docs.iter().map(|&d| vec![0; corpus.docs[d].len()]).collect();

    // Init: random assignments, counted into the shared tables through
    // the write-back buffer (one lock per chunk, not per token).
    let mut wbuf: Vec<(RowId, u32, f32)> = Vec::with_capacity(4 * WB_FLUSH);
    let mut tsbuf = vec![0.0f32; k];
    for (li, &d) in my_docs.iter().enumerate() {
        for (ti, &w) in corpus.docs[d].iter().enumerate() {
            let t = rng.below(k) as u16;
            z[li][ti] = t;
            n_dk[li][t as usize] += 1.0;
            wbuf.push((RowId(w as u64), t as u32, 1.0));
            tsbuf[t as usize] += 1.0;
            if wbuf.len() >= WB_FLUSH {
                wt.inc_many(&wbuf).unwrap();
                wbuf.clear();
            }
        }
    }
    wt.inc_many(&wbuf).unwrap();
    wbuf.clear();
    ts.inc_row(RowId(0), &tsbuf).unwrap();
    tsbuf.iter_mut().for_each(|x| *x = 0.0);
    ctx.clock().unwrap(); // sweep 0 boundary: ship the init counts

    let vbeta = corpus.vocab as f32 * cfg.beta;
    let mut tokens: u64 = 0;
    let mut loglik = Vec::with_capacity(cfg.sweeps);

    // Reusable buffers for the hot loop (perf pass: no per-token
    // allocation, writes batched through the thread-cache buffer, the
    // topic-sum row cached per document — the paper's thread-cache
    // discipline; staleness stays bounded by one document).
    let mut probs = vec![0.0f32; k];
    let mut nw = vec![0.0f32; k];
    for _sweep in 0..cfg.sweeps {
        let mut ll_sum = 0.0f64;
        let mut ll_n = 0u64;
        for (li, &d) in my_docs.iter().enumerate() {
            // Straggler simulation hook: per-document extra think time.
            if ctx.is_straggler() {
                ctx.straggle(std::time::Duration::from_micros(200));
            }
            let doc = &corpus.docs[d];
            // Optionally compute all token probs for this doc via the AOT
            // artifact (batched; trades per-token freshness for MXU work —
            // the standard batched-sampler approximation).
            let xla_probs = pool.as_ref().map(|pool| {
                xla_doc_probs(pool, &wt, &ts, doc, &n_dk[li], cfg, vbeta).unwrap()
            });
            // Thread-cached topic sums: one PS read per document, local
            // deltas applied as this doc's tokens move between topics.
            let mut nk_local = ts.get_row(RowId(0)).unwrap();
            for (ti, &w) in doc.iter().enumerate() {
                let old = z[li][ti] as usize;
                // remove token from counts
                n_dk[li][old] -= 1.0;
                if let Some(ref pm) = xla_probs {
                    probs.copy_from_slice(&pm[ti * k..(ti + 1) * k]);
                } else {
                    wt.get_row_into(RowId(w as u64), &mut nw).unwrap();
                    for t in 0..k {
                        let nwt = (nw[t] + if t == old { -1.0 } else { 0.0 }).max(0.0);
                        let nkt =
                            (nk_local[t] + if t == old { -1.0 } else { 0.0 }).max(0.0);
                        probs[t] =
                            (n_dk[li][t] + cfg.alpha) * (nwt + cfg.beta) / (nkt + vbeta);
                    }
                }
                let new = sample_discrete(&mut rng, &probs);
                // log-lik proxy: probability mass of the chosen topic
                let total: f32 = probs.iter().sum();
                if total > 0.0 {
                    ll_sum += ((probs[new] / total) as f64).max(1e-12).ln();
                    ll_n += 1;
                }
                z[li][ti] = new as u16;
                n_dk[li][new] += 1.0;
                if new != old {
                    wbuf.push((RowId(w as u64), old as u32, -1.0));
                    wbuf.push((RowId(w as u64), new as u32, 1.0));
                    tsbuf[old] -= 1.0;
                    tsbuf[new] += 1.0;
                    nk_local[old] -= 1.0;
                    nk_local[new] += 1.0;
                    if wbuf.len() >= WB_FLUSH {
                        wt.inc_many(&wbuf).unwrap();
                        wbuf.clear();
                    }
                }
                tokens += 1;
            }
            // Per-document write-back of the topic-sum deltas.
            if tsbuf.iter().any(|&x| x != 0.0) {
                ts.inc_row(RowId(0), &tsbuf).unwrap();
                tsbuf.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        wt.inc_many(&wbuf).unwrap();
        wbuf.clear();
        loglik.push(if ll_n > 0 { ll_sum / ll_n as f64 } else { 0.0 });
        ctx.clock().unwrap();
    }
    (tokens, loglik)
}

/// Write-back buffer flush threshold (tokens' worth of deltas held in the
/// thread cache before one bulk `inc_many`).
const WB_FLUSH: usize = 128;

/// Batched topic-probability computation through the `lda_topic_probs`
/// artifact: inputs `n_wk [B,K]`, `n_dk [K]`, `n_k [K]`, priors; output
/// `probs [B,K]` (flattened).
fn xla_doc_probs(
    pool: &ComputePool,
    wt: &crate::client::TableHandle,
    ts: &crate::client::TableHandle,
    doc: &[u32],
    n_dk: &[f32],
    cfg: &LdaConfig,
    vbeta: f32,
) -> Result<Vec<f32>> {
    let k = cfg.num_topics;
    let b = doc.len();
    let mut nwk = Vec::with_capacity(b * k);
    for &w in doc {
        nwk.extend(wt.get_row(RowId(w as u64))?);
    }
    let nk = ts.get_row(RowId(0))?;
    let out = pool.run(
        "lda_topic_probs",
        vec![
            Tensor::new(nwk, vec![b, k])?,
            Tensor::new(n_dk.to_vec(), vec![k])?,
            Tensor::new(nk, vec![k])?,
            Tensor::scalar(cfg.alpha),
            Tensor::scalar(cfg.beta),
            Tensor::scalar(vbeta),
        ],
    )?;
    Ok(out.into_iter().next().map(|t| t.data).unwrap_or_default())
}

/// Sample an index proportional to `weights` (non-negative; falls back to
/// uniform if all mass vanished).
fn sample_discrete(rng: &mut Rng64, weights: &[f32]) -> usize {
    let total: f32 = weights.iter().sum();
    if total <= 0.0 || !total.is_finite() {
        return rng.below(weights.len());
    }
    let mut u: f32 = rng.f32() * total;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::lda::corpus::SyntheticCorpusConfig;
    use crate::config::SystemConfig;

    #[test]
    fn sample_discrete_respects_mass() {
        let mut rng = Rng64::seed_from_u64(1);
        let w = [0.0f32, 0.0, 1.0, 0.0];
        for _ in 0..50 {
            assert_eq!(sample_discrete(&mut rng, &w), 2);
        }
        // degenerate: all-zero falls back to uniform without panicking
        let z = [0.0f32; 4];
        let i = sample_discrete(&mut rng, &z);
        assert!(i < 4);
    }

    #[test]
    fn tiny_lda_end_to_end_counts_are_conserved() {
        let cfg = SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(1)
            .flush_interval_us(50)
            .build();
        let sys = PsSystem::launch(cfg).unwrap();
        let corpus = Arc::new(Corpus::synthetic(&SyntheticCorpusConfig::news20_scaled(2000)));
        let lda = LdaConfig {
            num_topics: 8,
            sweeps: 2,
            policy: PolicyConfig::Vap { v_thr: 16.0, strong: false },
            ..LdaConfig::default()
        };
        let res = run_lda(&sys, corpus.clone(), lda, None).unwrap();
        let total_tokens = corpus.stats().num_tokens as u64;
        assert_eq!(res.tokens_processed, total_tokens * 2, "each sweep touches every token");
        assert!(res.tokens_per_sec > 0.0);

        // Conservation: once every update has propagated, the topic-sum
        // row must total the corpus token count. VAP has no clock gate, so
        // poll until the async pipeline drains (bounded wait).
        let reader = sys
            .run_workers(move |ctx| {
                let ts = ctx.table(TOPIC_SUM_TABLE);
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
                loop {
                    let row = ts.get_row(RowId(0)).unwrap();
                    let sum: f32 = row.iter().sum();
                    if sum as u64 == total_tokens || std::time::Instant::now() > deadline {
                        return sum;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            })
            .unwrap();
        for sum in reader {
            assert_eq!(sum as i64, total_tokens as i64, "topic-sum must conserve tokens");
        }
        sys.shutdown().unwrap();
    }
}
