//! Latent Dirichlet Allocation by collapsed Gibbs sampling over the
//! parameter server — the paper's §5 evaluation workload.
//!
//! Tables (all f32 counts):
//! * **word-topic** `n_wk` — one row per vocabulary word, `K` columns;
//!   the contended, shared state. The paper runs it under **weak VAP**.
//! * **topic-sum** `n_k` — a single row of `K` totals.
//!
//! Doc-topic counts `n_dk` and topic assignments `z` are worker-local
//! (documents are partitioned across workers), the standard layout of
//! distributed LDA (YahooLDA, Petuum).
//!
//! The sampler supports two inner-loop implementations:
//! * pure Rust (default — the throughput path used for the Fig-5 scaling
//!   bench);
//! * the JAX/Pallas AOT artifact `lda_topic_probs` via
//!   [`crate::runtime::ComputePool`] (E2E validation that the three-layer
//!   stack composes; batches a document's tokens per call).

mod corpus;
mod gibbs;

pub use corpus::{Corpus, CorpusStats, SyntheticCorpusConfig};
pub use gibbs::{run_lda, GibbsResult, LdaConfig};
