//! ML applications on the parameter server — the workloads the paper's
//! evaluation and theory sections use:
//!
//! * [`lda`] — collapsed-Gibbs Latent Dirichlet Allocation over PS tables
//!   (the paper's §5 evaluation: 20News-scale corpus, weak VAP, strong
//!   scaling);
//! * [`sgd`] — stochastic gradient descent for logistic/linear regression
//!   (the Theorem-1 workload), with the gradient computed either by a
//!   pure-Rust path or by the JAX/Pallas AOT artifact via PJRT;
//! * [`mf`] — matrix factorization by SGD (a second realistic workload);
//! * [`transformer`] — data-parallel transformer-LM training driver (the
//!   end-to-end validation workload, E8 in DESIGN.md).

pub mod lda;
pub mod mf;
pub mod sgd;
pub mod transformer;
