//! The paper's consistency models (§2) as executable gate logic.
//!
//! Every model is expressed as a *Consistency Policy* — pure decision
//! functions over local state — consulted by the *Consistency Controller*
//! machinery inside the client library and server shards (paper §4.3,
//! Fig 3: "each table is associated with a Consistency Controller, which
//! checks Consistency Policy and services user accesses accordingly").
//!
//! The four models, and where their gates act:
//!
//! | model | read gate (client) | write gate (client) | release gate (server) | propagation |
//! |-------|--------------------|---------------------|----------------------|-------------|
//! | BSP   | clock bound s=0    | —                   | —                    | at `Clock()` |
//! | SSP   | clock bound s      | —                   | —                    | at `Clock()` |
//! | CAP   | clock bound s      | —                   | —                    | eager        |
//! | VAP (weak)  | —            | value bound v_thr   | —                    | eager        |
//! | VAP (strong)| —            | value bound v_thr   | half-sync bound      | eager        |
//! | CVAP  | clock bound s      | value bound v_thr   | (strong: half-sync)  | eager        |
//!
//! All models additionally guarantee **read-my-writes** (a worker's `Get`
//! always reflects its own `Inc`s — implemented by overlaying the local
//! op-log on the cached snapshot) and **FIFO consistency** (updates from a
//! worker become visible in issue order — implemented by monotone batch
//! ids over per-link FIFO channels). Those two are structural: they hold
//! for every policy including `BestEffort`.

pub mod cap;
pub mod cvap;
pub mod ssp;
pub mod vap;

use crate::config::PolicyConfig;
use crate::types::Clock;

/// A compiled consistency policy: the per-access decision functions for
/// one table. Constructed from [`PolicyConfig`]; immutable afterwards.
#[derive(Debug, Clone, Copy)]
pub struct ConsistencyModel {
    cfg: PolicyConfig,
}

impl ConsistencyModel {
    /// Compile a policy config.
    pub fn new(cfg: PolicyConfig) -> Self {
        ConsistencyModel { cfg }
    }

    /// The underlying config.
    pub fn config(&self) -> PolicyConfig {
        self.cfg
    }

    /// Human-readable name (for metrics/bench rows).
    pub fn name(&self) -> String {
        self.cfg.name()
    }

    /// **Read gate.** The minimum row freshness (clock) a reader at clock
    /// `c` may accept. A cached row with clock `r ≥ required` can be served
    /// locally; otherwise the reader must pull and possibly block.
    ///
    /// Clock-bounded models (BSP/SSP/CAP/CVAP, paper §2.1): a worker at
    /// clock `c` must see all updates in `[0, c−s−1]`, so the required
    /// freshness is `c − s − 1` (saturating at 0: young readers never
    /// block). Value-only and best-effort models never require freshness.
    pub fn required_read_clock(&self, reader_clock: Clock) -> Clock {
        match self.cfg {
            PolicyConfig::Bsp => ssp::required_read_clock(reader_clock, 0),
            PolicyConfig::Ssp { staleness } | PolicyConfig::Cap { staleness } => {
                ssp::required_read_clock(reader_clock, staleness)
            }
            PolicyConfig::Cvap { staleness, .. } => {
                ssp::required_read_clock(reader_clock, staleness)
            }
            PolicyConfig::Vap { .. } | PolicyConfig::BestEffort => 0,
        }
    }

    /// **Write gate.** Should an `Inc` of `delta` on a parameter whose
    /// signed accumulated unsynchronized sum is `pending_sum` block?
    /// (VAP/CVAP only, paper §2.2 / Fig 1.)
    pub fn write_blocked(&self, pending_sum: f32, delta: f32) -> bool {
        match self.cfg {
            PolicyConfig::Vap { v_thr, .. } | PolicyConfig::Cvap { v_thr, .. } => {
                vap::write_blocked(pending_sum, delta, v_thr)
            }
            _ => false,
        }
    }

    /// **Server release gate** (strong VAP/CVAP only, paper §2.2): may the
    /// shard forward a batch contributing `batch_l1` to a parameter whose
    /// current half-synchronized in-flight magnitude is `inflight_l1`,
    /// given the largest single-update magnitude `u_obs` observed so far?
    pub fn release_blocked(&self, inflight_l1: f32, batch_l1: f32, u_obs: f32) -> bool {
        match self.cfg {
            PolicyConfig::Vap { v_thr, strong: true }
            | PolicyConfig::Cvap { v_thr, strong: true, .. } => {
                vap::release_blocked(inflight_l1, batch_l1, u_obs, v_thr)
            }
            _ => false,
        }
    }

    /// Whether [`Self::release_blocked`] can ever return `true` — i.e.
    /// whether this model carries the strong-VAP/CVAP server release gate.
    /// When it cannot, the shard skips per-parameter in-flight mass
    /// accounting entirely: for ungated models that bookkeeping is pure
    /// per-push overhead (two hash operations per nonzero column) feeding a
    /// gate that is a constant `false`.
    pub fn release_gated(&self) -> bool {
        matches!(
            self.cfg,
            PolicyConfig::Vap { strong: true, .. } | PolicyConfig::Cvap { strong: true, .. }
        )
    }

    /// Does this model propagate updates eagerly (async flusher active)
    /// rather than only at the clock boundary?
    pub fn eager_propagation(&self) -> bool {
        self.cfg.is_async()
    }

    /// The staleness bound, if any.
    pub fn staleness(&self) -> Option<u32> {
        self.cfg.staleness()
    }

    /// The value threshold, if any.
    pub fn v_thr(&self) -> Option<f32> {
        self.cfg.v_thr()
    }

    /// Theoretical replica-divergence bound `max |θ_A − θ_B|` for `P`
    /// workers given the largest update magnitude `u` (paper §2.2):
    /// weak VAP ⇒ `max(u, v_thr) · P`; strong VAP ⇒ `2 · max(u, v_thr)`;
    /// clock-only and best-effort models have no value-divergence bound.
    pub fn divergence_bound(&self, p: u32, u: f32) -> Option<f32> {
        match self.cfg {
            PolicyConfig::Vap { v_thr, strong } | PolicyConfig::Cvap { v_thr, strong, .. } => {
                Some(vap::divergence_bound(v_thr, strong, p, u))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bsp_is_zero_staleness_ssp() {
        // The paper's BSP Lemma: zero-staleness CVAP/SSP reduces to BSP.
        let bsp = ConsistencyModel::new(PolicyConfig::Bsp);
        let ssp0 = ConsistencyModel::new(PolicyConfig::Ssp { staleness: 0 });
        for c in 0..20 {
            assert_eq!(bsp.required_read_clock(c), ssp0.required_read_clock(c));
        }
        assert_eq!(bsp.required_read_clock(5), 4);
    }

    #[test]
    fn clock_gate_saturates_for_young_readers() {
        let m = ConsistencyModel::new(PolicyConfig::Cap { staleness: 3 });
        assert_eq!(m.required_read_clock(0), 0);
        assert_eq!(m.required_read_clock(3), 0);
        assert_eq!(m.required_read_clock(4), 0);
        assert_eq!(m.required_read_clock(5), 1);
        assert_eq!(m.required_read_clock(10), 6);
    }

    #[test]
    fn vap_has_no_clock_gate_and_cap_no_value_gate() {
        let vap = ConsistencyModel::new(PolicyConfig::Vap { v_thr: 8.0, strong: false });
        assert_eq!(vap.required_read_clock(100), 0);
        assert!(vap.write_blocked(8.0, 1.0));

        let cap = ConsistencyModel::new(PolicyConfig::Cap { staleness: 1 });
        assert!(!cap.write_blocked(1e9, 1e9));
    }

    #[test]
    fn cvap_combines_both_gates() {
        let m = ConsistencyModel::new(PolicyConfig::Cvap { staleness: 2, v_thr: 4.0, strong: false });
        assert_eq!(m.required_read_clock(10), 7);
        assert!(m.write_blocked(3.5, 1.0));
        assert!(!m.write_blocked(2.0, 1.0));
    }

    #[test]
    fn release_gate_only_for_strong() {
        let weak = ConsistencyModel::new(PolicyConfig::Vap { v_thr: 2.0, strong: false });
        assert!(!weak.release_gated());
        assert!(!weak.release_blocked(100.0, 100.0, 1.0));
        let strong = ConsistencyModel::new(PolicyConfig::Vap { v_thr: 2.0, strong: true });
        assert!(strong.release_gated());
        assert!(strong.release_blocked(2.0, 1.0, 1.0));
        assert!(!strong.release_blocked(0.0, 1.0, 1.0));
        assert!(!ConsistencyModel::new(PolicyConfig::Ssp { staleness: 2 }).release_gated());
        assert!(!ConsistencyModel::new(PolicyConfig::BestEffort).release_gated());
        let cvap = PolicyConfig::Cvap { staleness: 1, v_thr: 2.0, strong: true };
        assert!(ConsistencyModel::new(cvap).release_gated());
    }

    #[test]
    fn divergence_bounds_match_paper() {
        // weak: max(u, v_thr) * P ; strong: 2 * max(u, v_thr)
        let weak = ConsistencyModel::new(PolicyConfig::Vap { v_thr: 8.0, strong: false });
        assert_eq!(weak.divergence_bound(4, 2.0), Some(32.0));
        let strong = ConsistencyModel::new(PolicyConfig::Vap { v_thr: 8.0, strong: true });
        assert_eq!(strong.divergence_bound(4, 2.0), Some(16.0));
        // u > v_thr dominates
        assert_eq!(strong.divergence_bound(4, 10.0), Some(20.0));
        let cap = ConsistencyModel::new(PolicyConfig::Cap { staleness: 1 });
        assert_eq!(cap.divergence_bound(4, 1.0), None);
    }

    #[test]
    fn eager_propagation_flags() {
        assert!(!ConsistencyModel::new(PolicyConfig::Bsp).eager_propagation());
        assert!(!ConsistencyModel::new(PolicyConfig::Ssp { staleness: 5 }).eager_propagation());
        assert!(ConsistencyModel::new(PolicyConfig::Cap { staleness: 5 }).eager_propagation());
        assert!(ConsistencyModel::new(PolicyConfig::Vap { v_thr: 1.0, strong: false })
            .eager_propagation());
        assert!(ConsistencyModel::new(PolicyConfig::BestEffort).eager_propagation());
    }
}
