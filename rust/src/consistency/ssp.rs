//! Stale Synchronous Parallel (SSP) — the baseline bounded-staleness model
//! of Ho et al. [NIPS 2013], which the paper's CAP generalizes to the
//! asynchronous setting (§1, §2.1).
//!
//! Semantics: execution proceeds in clocks; updates generated in the
//! interval `(c−1, c]` are timestamped `c` and are shipped during the
//! synchronization phase of `Clock()`. A worker at clock `c` is guaranteed
//! to observe **all** updates (from every worker) with timestamp
//! `≤ c − s − 1`, plus its own writes; a worker may run at most `s` clocks
//! ahead of the slowest worker before its reads force it to wait.
//!
//! With `s = 0` this is Bulk Synchronous Parallel — the paper's BSP Lemma.

use crate::types::Clock;

/// The freshness (row clock) a reader at `reader_clock` requires under
/// staleness bound `s`: all updates timestamped `≤ reader_clock − s − 1`
/// must be visible. Saturates at 0 so workers in their first `s+1` clocks
/// never block; `s + 1` itself saturates so `s = u32::MAX` means
/// "unbounded staleness" rather than overflowing back to a tight bound.
pub fn required_read_clock(reader_clock: Clock, s: u32) -> Clock {
    reader_clock.saturating_sub(s.saturating_add(1))
}

/// The maximum clock a worker may reach before the gate can possibly make
/// it wait on a peer at `min_clock`: `min_clock + s + 1`, saturating at
/// `u32::MAX`. (At that clock its reads require freshness `min_clock`,
/// exactly the frontier.) Used by tests to check the permitted-lead
/// invariant.
pub fn max_permitted_clock(min_clock: Clock, s: u32) -> Clock {
    min_clock.saturating_add(s).saturating_add(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn required_clock_formula() {
        // reader at clock c needs updates in [0, c-s-1]
        assert_eq!(required_read_clock(10, 2), 7);
        assert_eq!(required_read_clock(1, 0), 0);
        assert_eq!(required_read_clock(2, 0), 1); // BSP: barrier on c-1
        assert_eq!(required_read_clock(0, 3), 0);
        assert_eq!(required_read_clock(3, 3), 0);
    }

    #[test]
    fn required_clock_saturates_at_extremes() {
        // s = u32::MAX encodes unbounded staleness: no read ever blocks,
        // even for a reader at the maximum clock. Without the inner
        // saturating_add this would overflow to s+1 = 0 and demand full
        // freshness — the exact opposite semantics.
        assert_eq!(required_read_clock(u32::MAX, u32::MAX), 0);
        assert_eq!(required_read_clock(10, u32::MAX), 0);
        assert_eq!(required_read_clock(0, u32::MAX), 0);
        // BSP (s = 0) at the clock ceiling still requires c − 1.
        assert_eq!(required_read_clock(u32::MAX, 0), u32::MAX - 1);
        // Clock 0 readers never block regardless of s.
        for s in [0, 1, 7, u32::MAX] {
            assert_eq!(required_read_clock(0, s), 0);
        }
    }

    #[test]
    fn max_permitted_clock_saturates() {
        assert_eq!(max_permitted_clock(u32::MAX, 0), u32::MAX);
        assert_eq!(max_permitted_clock(0, u32::MAX), u32::MAX);
        assert_eq!(max_permitted_clock(u32::MAX - 1, 0), u32::MAX);
        assert_eq!(max_permitted_clock(0, 0), 1);
    }

    #[test]
    fn permitted_lead_matches_gate() {
        // A worker at the permitted max clock requires exactly min_clock;
        // one clock beyond would require min_clock+1 which isn't there yet.
        for s in 0..5u32 {
            for min in 0..5u32 {
                let max_c = max_permitted_clock(min, s);
                assert_eq!(required_read_clock(max_c, s), min);
                assert_eq!(required_read_clock(max_c + 1, s), min + 1);
            }
        }
    }
}
