//! Value-bounded Asynchronous Parallel (VAP), weak and strong — paper §2.2.
//!
//! **Weak VAP** guarantees that for any worker, the accumulated sum of
//! *unsynchronized local updates* of any parameter stays below a
//! user-defined threshold `v_thr`. A worker attempting an update that
//! would push the accumulated magnitude past `v_thr` blocks until the
//! system has made enough of its earlier updates visible to all workers
//! (Fig 1). The resulting replica-divergence bound is
//! `|θ_A − θ_B| ≤ max(u, v_thr) · P` where `u` bounds any single update's
//! magnitude and `P` is the number of workers.
//!
//! **Strong VAP** additionally bounds the total magnitude of
//! *half-synchronized* updates — updates seen by at least one other worker
//! but not yet by all — to `max(u, v_thr)` per parameter. The divergence
//! bound tightens to `2 · max(u, v_thr)`, independent of `P`. We implement
//! the half-sync bound as a **server-side release gate**: a shard defers
//! forwarding a batch to the caching clients while the parameter's
//! in-flight (forwarded-but-not-fully-acked) magnitude would exceed the
//! bound.
//!
//! ### Accounting note
//! The implementation tracks the accumulated **L1 mass** of
//! unsynchronized updates per parameter at *process* granularity (the
//! client library is the synchronization unit, as in Petuum PS). Since
//! L1 mass is additive over the process's workers and dominates each
//! worker's absolute accumulated sum, enforcing `L1 < v_thr` per process
//! implies the paper's per-worker bound — it is conservative, never
//! looser. Tests in `tests/consistency_bounds.rs` verify the per-worker
//! bound directly from traces.

/// Weak-VAP write gate over the parameter's **signed accumulated sum** of
/// unsynchronized updates (the paper's "accumulated sum s of
/// unsynchronized local updates"): block when the parameter already has
/// pending mass and applying `delta` would take `|pending + delta|` past
/// `v_thr`. Signed accounting matters: a `+1` followed by a `-1` leaves
/// zero net divergence and must not consume budget (LDA's topic counts
/// oscillate exactly like this).
///
/// The `pending != 0` conjunct prevents a single update larger than
/// `v_thr` from deadlocking forever: the paper's divergence bound already
/// accounts for oversized single updates through `u` (`max(u, v_thr)`),
/// so letting a lone oversized update through preserves the bound.
pub fn write_blocked(pending_sum: f32, delta: f32, v_thr: f32) -> bool {
    pending_sum != 0.0 && (pending_sum + delta).abs() > v_thr
}

/// Strong-VAP server release gate: defer forwarding when the parameter's
/// half-synchronized in-flight mass plus the batch's contribution would
/// exceed `max(u_obs, v_thr)`. As with the write gate, an idle parameter
/// (`inflight == 0`) always admits the next batch so oversized batches
/// cannot wedge the pipeline (their excess is covered by `u`).
pub fn release_blocked(inflight_l1: f32, batch_l1: f32, u_obs: f32, v_thr: f32) -> bool {
    inflight_l1 > 0.0 && inflight_l1 + batch_l1 > v_thr.max(u_obs)
}

/// The paper's replica-divergence bound for VAP (§2.2): weak VAP gives
/// `max(u, v_thr) · P`, strong VAP gives `2 · max(u, v_thr)` (independent
/// of `P`).
pub fn divergence_bound(v_thr: f32, strong: bool, p: u32, u: f32) -> f32 {
    let m = v_thr.max(u);
    if strong {
        2.0 * m
    } else {
        m * p as f32
    }
}

/// Lemma 1's bound on the reference-vs-noisy-view discrepancy under VAP:
/// `|A_t| + |B_t| ≤ 2 · v_thr · (P − 1)` — the missing-plus-extra update
/// mass between the true sequence `x_t` and any worker's noisy view.
/// Benches compare measured discrepancies against this.
pub fn lemma1_bound(v_thr: f32, p: u32) -> f32 {
    2.0 * v_thr * (p.saturating_sub(1)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_schedule() {
        // Figure 1: v_thr = 8; updates (1,1),(2,3),(3,2),(4,1),(5,1) — the
        // accumulated sum reaches 8; applying (6,2) would exceed it.
        let v_thr = 8.0;
        let deltas = [1.0f32, 3.0, 2.0, 1.0, 1.0];
        let mut pending = 0.0;
        for d in deltas {
            assert!(!write_blocked(pending, d, v_thr), "update {d} must not block");
            pending += d;
        }
        assert_eq!(pending, 8.0);
        // (6,2) blocks
        assert!(write_blocked(pending, 2.0, v_thr));
        // after updates 1..4 become visible (mass 7 released): pending = 1
        pending -= 7.0;
        assert!(!write_blocked(pending, 2.0, v_thr), "(6,2) proceeds after release");
    }

    #[test]
    fn oversized_single_update_is_admitted_when_idle() {
        assert!(!write_blocked(0.0, 100.0, 8.0));
        assert!(write_blocked(0.1, 100.0, 8.0));
    }

    #[test]
    fn signed_cancellation_does_not_consume_budget() {
        // pending +7 with a -2 delta nets to 5 ≤ 8: must not block.
        assert!(!write_blocked(7.0, -2.0, 8.0));
        // pending -7 with another -2 nets to -9: blocks.
        assert!(write_blocked(-7.0, -2.0, 8.0));
        // symmetric on the negative side
        assert!(!write_blocked(-7.0, 2.0, 8.0));
    }

    #[test]
    fn write_gate_negative_and_mixed_sign_edges() {
        // Saturated on the negative side: another push outward blocks...
        assert!(write_blocked(-8.0, -1.0, 8.0));
        // ...but a pull back toward zero never does.
        assert!(!write_blocked(-8.0, 1.0, 8.0));
        // A large opposite-sign delta that lands back inside the band
        // (|4 − 9| = 5 ≤ 8) is admitted — signed accounting, not L1.
        assert!(!write_blocked(4.0, -9.0, 8.0));
        // A small pending with a big same-sign delta overshoots: blocks.
        assert!(write_blocked(-0.5, -8.0, 8.0));
        // The idle-parameter escape hatch works for arbitrarily large
        // deltas regardless of sign.
        assert!(!write_blocked(0.0, 100.0, 8.0));
        assert!(!write_blocked(0.0, -100.0, 8.0));
    }

    #[test]
    fn release_gate_oversize_observed_u() {
        // u_obs = 10 > v_thr = 2 ⇒ the release bound is 10, not 2.
        // inflight 5 + batch 4 = 9 ≤ 10: admitted.
        assert!(!release_blocked(5.0, 4.0, 10.0, 2.0));
        // inflight 5 + batch 6 = 11 > 10: held.
        assert!(release_blocked(5.0, 6.0, 10.0, 2.0));
        // Idle parameter always admits, even past both bounds.
        assert!(!release_blocked(0.0, 100.0, 10.0, 2.0));
    }

    #[test]
    fn release_gate_uses_max_of_u_and_vthr() {
        // bound = max(u, v_thr) = 10
        assert!(!release_blocked(4.0, 6.0, 10.0, 8.0));
        assert!(release_blocked(4.1, 6.0, 10.0, 8.0));
        // bound = v_thr when it dominates
        assert!(release_blocked(4.0, 6.0, 1.0, 8.0));
        // idle parameter always admits
        assert!(!release_blocked(0.0, 1e6, 1.0, 8.0));
    }

    #[test]
    fn divergence_bounds() {
        assert_eq!(divergence_bound(8.0, false, 4, 2.0), 32.0);
        assert_eq!(divergence_bound(8.0, true, 4, 2.0), 16.0);
        assert_eq!(divergence_bound(8.0, true, 1000, 2.0), 16.0, "strong is P-independent");
        assert_eq!(divergence_bound(2.0, false, 3, 5.0), 15.0, "u dominates");
    }

    #[test]
    fn lemma1_bound_shape() {
        assert_eq!(lemma1_bound(4.0, 1), 0.0, "single worker: no discrepancy");
        assert_eq!(lemma1_bound(4.0, 5), 32.0);
        assert!(lemma1_bound(4.0, 9) > lemma1_bound(4.0, 5), "grows with P");
    }
}
