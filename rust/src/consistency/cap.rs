//! Clock-bounded Asynchronous Parallel (CAP) — paper §2.1.
//!
//! CAP applies SSP's clock-bounded guarantee to an **asynchronous**
//! parameter server: "unlike SSP where updates are sent out only during
//! the synchronization phase, CAP propagates updates whenever the network
//! bandwidth is available. Similar to SSP, CAP guarantees bounded
//! staleness — a client must see all updates older than certain
//! timestamp."
//!
//! The read gate is therefore *identical* to SSP's
//! ([`super::ssp::required_read_clock`]); what differs is the propagation
//! discipline, which in this implementation is the client's background
//! flusher ([`crate::client`]) draining the egress queue every
//! `flush_interval_us` instead of only inside `Clock()`. The algorithmic
//! upside the paper claims — workers "are more likely to compute with
//! fresh data" — is measurable here as the staleness *distribution*
//! ([`crate::metrics::StalenessHist`]): CAP's observed staleness
//! concentrates near 0 while SSP's piles up at `s`.
//!
//! Correctness: the staleness analysis of Ho et al. applies unchanged
//! ("we omit the proof of correctness for CAP as the analysis in [5]
//! applies as well", §2.1) — eager propagation only ever *adds*
//! best-effort in-window updates, term 3 of the paper's eq. (1).

use crate::types::Clock;

/// Expected upper bound on observed read staleness under CAP with bound
/// `s`: the gate admits rows as stale as `s + 1` clocks behind the
/// reader's current clock (reader at `c` accepts freshness `c − s − 1`).
/// Used by tests asserting the guarantee empirically.
pub fn max_observable_staleness(s: u32) -> Clock {
    s + 1
}

/// Whether a cached row of freshness `row_clock` satisfies a reader at
/// `reader_clock` under staleness `s` — the CAP/SSP read predicate in one
/// place (clients call this; the controller in `client/` wires it up).
pub fn read_admissible(reader_clock: Clock, row_clock: Clock, s: u32) -> bool {
    row_clock >= super::ssp::required_read_clock(reader_clock, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admissibility_boundaries() {
        // reader at 10, s=2 ⇒ requires row clock ≥ 7
        assert!(read_admissible(10, 7, 2));
        assert!(read_admissible(10, 9, 2));
        assert!(!read_admissible(10, 6, 2));
        // young reader never blocks
        assert!(read_admissible(2, 0, 2));
    }

    #[test]
    fn observable_staleness_bound() {
        // If every read is admissible, observed staleness (reader_clock −
        // row_clock) never exceeds s+1.
        let s = 3;
        for reader in 0..50u32 {
            for row in 0..50u32 {
                if read_admissible(reader, row, s) && reader >= row {
                    assert!(reader - row <= max_observable_staleness(s));
                }
            }
        }
    }
}
