//! Clock-Value-bounded Asynchronous Parallel (CVAP) — paper §2.3.
//!
//! CVAP is the conjunction of CAP and VAP: "the idea is that CVAP ensures
//! all workers make enough progress but bounds the absolute difference
//! between replicas. CVAP provides the consistency guarantees of both CAP
//! and VAP." Like VAP it comes in weak and strong versions.
//!
//! There is no new gate logic here — the client controller applies the
//! CAP read gate ([`super::ssp::required_read_clock`]) *and* the VAP write
//! gate ([`super::vap::write_blocked`]); a strong CVAP shard additionally
//! applies the release gate ([`super::vap::release_blocked`]). What CVAP
//! buys, per the paper's §3, is that the solution quality of an iterative
//! algorithm can be *assessed*: the clock bound caps how many update
//! windows any view can be missing, the value bound caps the mass of each,
//! so the noisy-view error (Lemma 1 / eq. (2)) is controlled in both
//! count and magnitude — which is what makes Theorem 1's `O(√T)` regret
//! hold with constants the application can tune.
//!
//! This module contributes the combined-bound arithmetic used by the
//! benches and property tests.

use crate::types::Clock;

/// The combined view-discrepancy bound CVAP certifies: with staleness `s`,
/// value bound `v_thr`, `P` workers and per-update magnitude bound `u`, a
/// noisy view can miss (or have extra) at most `(s + 1) · (P − 1)` update
/// *windows* of peers, each window carrying at most `max(u, v_thr)` mass
/// (weak), i.e. `mass ≤ (s + 1) · (P − 1) · max(u, v_thr)`.
pub fn view_discrepancy_bound(s: Clock, v_thr: f32, p: u32, u: f32) -> f32 {
    (s + 1) as f32 * p.saturating_sub(1) as f32 * v_thr.max(u)
}

/// Theorem 1's regret bound for SGD under VAP/CVAP:
/// `R[X] ≤ σL²√T + (F²/σ)√T + 2σL·v_thr·P·√T` with the paper's
/// `σ = F / (L·√(v_thr·P))`. Returns the bound's value; benches compare
/// measured regret against it.
pub fn theorem1_regret_bound(t: u64, l: f64, f: f64, v_thr: f64, p: u32) -> f64 {
    let sigma = f / (l * (v_thr * p as f64).sqrt());
    let sqrt_t = (t as f64).sqrt();
    sigma * l * l * sqrt_t + (f * f / sigma) * sqrt_t + 2.0 * sigma * l * v_thr * p as f64 * sqrt_t
}

/// The learning-rate schedule Theorem 1 assumes: `η_t = σ/√t` with
/// `σ = F / (L √(v_thr · P))`.
pub fn theorem1_eta(t: u64, l: f64, f: f64, v_thr: f64, p: u32) -> f64 {
    let sigma = f / (l * (v_thr * p as f64).sqrt());
    sigma / (t.max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discrepancy_bound_monotone_in_all_knobs() {
        let b = view_discrepancy_bound(1, 2.0, 4, 1.0);
        assert!(view_discrepancy_bound(2, 2.0, 4, 1.0) > b);
        assert!(view_discrepancy_bound(1, 3.0, 4, 1.0) > b);
        assert!(view_discrepancy_bound(1, 2.0, 5, 1.0) > b);
        assert_eq!(view_discrepancy_bound(1, 2.0, 1, 1.0), 0.0, "P=1 ⇒ no discrepancy");
    }

    #[test]
    fn regret_bound_is_o_sqrt_t() {
        // bound(4T)/bound(T) must be ≈ 2 (√ scaling)
        let b1 = theorem1_regret_bound(10_000, 1.0, 1.0, 4.0, 8);
        let b4 = theorem1_regret_bound(40_000, 1.0, 1.0, 4.0, 8);
        let ratio = b4 / b1;
        assert!((ratio - 2.0).abs() < 1e-9, "ratio={ratio}");
    }

    #[test]
    fn regret_bound_grows_with_vthr_and_p() {
        let base = theorem1_regret_bound(1000, 1.0, 1.0, 1.0, 2);
        assert!(theorem1_regret_bound(1000, 1.0, 1.0, 4.0, 2) > base);
        assert!(theorem1_regret_bound(1000, 1.0, 1.0, 1.0, 8) > base);
    }

    #[test]
    fn eta_schedule_decays_as_inverse_sqrt() {
        let e1 = theorem1_eta(1, 1.0, 1.0, 4.0, 4);
        let e4 = theorem1_eta(4, 1.0, 1.0, 4.0, 4);
        assert!((e1 / e4 - 2.0).abs() < 1e-12);
        // t = 0 is clamped, not a division by zero
        assert!(theorem1_eta(0, 1.0, 1.0, 4.0, 4).is_finite());
    }
}
