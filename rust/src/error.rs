//! Library-wide error type.
//!
//! A parameter server has three broad failure domains: configuration
//! (bad table descriptors, inconsistent topology), runtime (channel
//! disconnects during shutdown, PJRT load/compile failures) and API misuse
//! (unknown table ids, out-of-range columns). All are folded into one
//! [`Error`] enum so the public API can return a single [`Result`].

use crate::table::{RowId, TableId};
use crate::types::NodeId;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// All errors produced by the BAPPS library.
#[derive(Debug)]
pub enum Error {
    /// Invalid or inconsistent configuration detected at launch/creation.
    Config(String),
    /// A table id was used before the table was created.
    UnknownTable(TableId),
    /// A row id outside the table's `num_rows`.
    RowOutOfRange { table: TableId, row: RowId, num_rows: u64 },
    /// A column index outside the table's `row_width`.
    ColOutOfRange { table: TableId, col: u32, width: u32 },
    /// A message could not be delivered because the destination endpoint's
    /// channel is closed (normal during shutdown, an error elsewhere).
    Disconnected(NodeId),
    /// A blocking wait (CAP staleness wait, VAP visibility wait) exceeded
    /// the configured deadline — almost always a deadlock or a dead peer.
    WaitTimeout { what: String, waited_ms: u64 },
    /// The PJRT runtime failed to load/compile/execute an artifact.
    Runtime(String),
    /// An artifact file is missing — run `make artifacts` first.
    MissingArtifact(std::path::PathBuf),
    /// Worker panicked; carries the panic payload rendered to a string.
    WorkerPanic(String),
    /// Generic I/O error (config files, trace dumps).
    Io(std::io::Error),
    /// Anything else.
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::UnknownTable(t) => write!(f, "unknown table {:?}", t),
            Error::RowOutOfRange { table, row, num_rows } => {
                write!(f, "row {} out of range for table {:?} ({} rows)", row.0, table, num_rows)
            }
            Error::ColOutOfRange { table, col, width } => {
                write!(f, "column {col} out of range for table {:?} (width {width})", table)
            }
            Error::Disconnected(n) => write!(f, "endpoint {n} disconnected"),
            Error::WaitTimeout { what, waited_ms } => {
                write!(f, "timed out after {waited_ms} ms waiting for {what}")
            }
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::MissingArtifact(p) => {
                write!(f, "missing artifact {} — run `make artifacts`", p.display())
            }
            Error::WorkerPanic(s) => write!(f, "worker panicked: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Other(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Other(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = Error::RowOutOfRange { table: TableId(3), row: RowId(42), num_rows: 10 };
        let s = e.to_string();
        assert!(s.contains("42") && s.contains("10"), "{s}");

        let e = Error::WaitTimeout { what: "VAP visibility".into(), waited_ms: 500 };
        assert!(e.to_string().contains("VAP visibility"));

        let e = Error::MissingArtifact("artifacts/x.hlo.txt".into());
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn io_error_source_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
