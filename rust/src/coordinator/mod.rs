//! The system coordinator: launches shards, client processes and worker
//! threads inside one OS process (the simulated cluster — see DESIGN.md §3
//! for why this substitution preserves the paper's phenomena).
//!
//! Topology (paper Fig 2): `num_server_shards` server threads, each the
//! event loop of a [`crate::server::ServerShard`]; `num_client_procs`
//! client "processes", each a [`crate::client::ClientCore`] with an
//! ingress thread, a flusher thread and `threads_per_proc` application
//! worker threads driven by [`PsSystem::run_workers`].

use std::sync::Arc;
use std::thread::JoinHandle;

use crate::client::{ClientCore, WorkerCtx};
use crate::comm::msg::{Msg, Payload};
use crate::comm::Network;
use crate::config::SystemConfig;
use crate::error::{Error, Result};
use crate::metrics::NetMetrics;
use crate::server::{ServerShard, TableRegistry};
use crate::table::TableDesc;
use crate::trace::TraceRecorder;
use crate::types::{NodeId, ProcId, ShardId, WorkerId};

/// A running parameter-server system.
///
/// ```no_run
/// use bapps::prelude::*;
/// let sys = PsSystem::launch(SystemConfig::default()).unwrap();
/// # sys.shutdown().unwrap();
/// ```
pub struct PsSystem {
    cfg: SystemConfig,
    registry: Arc<TableRegistry>,
    cores: Vec<Arc<ClientCore>>,
    trace: Arc<TraceRecorder>,
    network: Network,
    server_threads: Vec<JoinHandle<()>>,
    io_threads: Vec<JoinHandle<()>>,
}

impl PsSystem {
    /// Launch shards, client cores and their background threads.
    pub fn launch(cfg: SystemConfig) -> Result<Self> {
        cfg.validate()?;
        let network = Network::new(cfg.net.clone());
        let registry = Arc::new(TableRegistry::default());
        let trace = Arc::new(TraceRecorder::new(cfg.trace));

        // Register every endpoint before spawning anything, so no early
        // message can hit an unregistered mailbox.
        let mut shard_eps = Vec::new();
        for s in 0..cfg.num_server_shards {
            shard_eps.push(network.register(NodeId::Server(ShardId(s))));
        }
        let mut client_eps = Vec::new();
        for p in 0..cfg.num_client_procs {
            client_eps.push(network.register(NodeId::Client(ProcId(p))));
        }

        let mut server_threads = Vec::new();
        for (s, ep) in shard_eps.into_iter().enumerate() {
            let shard = ServerShard::with_trace(
                ShardId(s as u32),
                cfg.num_client_procs,
                registry.clone(),
                network.sender(),
                trace.clone(),
            );
            server_threads.push(
                std::thread::Builder::new()
                    .name(format!("shard{s}"))
                    .spawn(move || shard.run(ep))
                    .map_err(Error::Io)?,
            );
        }

        let mut cores = Vec::new();
        let mut io_threads = Vec::new();
        for (p, ep) in client_eps.into_iter().enumerate() {
            let core = Arc::new(ClientCore::new(
                ProcId(p as u32),
                cfg.clone(),
                registry.clone(),
                network.sender(),
                trace.clone(),
            ));
            let ingress = core.clone();
            io_threads.push(
                std::thread::Builder::new()
                    .name(format!("ingress{p}"))
                    .spawn(move || ingress.run_ingress(ep))
                    .map_err(Error::Io)?,
            );
            let flusher = core.clone();
            io_threads.push(
                std::thread::Builder::new()
                    .name(format!("flusher{p}"))
                    .spawn(move || flusher.run_flusher())
                    .map_err(Error::Io)?,
            );
            cores.push(core);
        }

        Ok(PsSystem { cfg, registry, cores, trace, network, server_threads, io_threads })
    }

    /// System configuration.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Create a table on every shard and client (lazily instantiated on
    /// first access). Must be called before workers touch the table.
    pub fn create_table(&self, desc: TableDesc) -> Result<()> {
        self.registry.insert(desc)
    }

    /// Run one closure on every worker thread (`P = procs × threads`),
    /// collecting their return values in worker-id order. Blocks until all
    /// workers finish; a panicking worker yields `Error::WorkerPanic`.
    pub fn run_workers<F, R>(&self, f: F) -> Result<Vec<R>>
    where
        F: Fn(&mut WorkerCtx) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let f = Arc::new(f);
        let tpp = self.cfg.threads_per_proc;
        let num_workers = self.cfg.num_workers();
        // Register every worker in its process vector clock BEFORE any
        // thread spawns: a fast-starting worker must never advance the
        // process min (and emit ClockNotify promises) while a late sibling
        // is still outside the clock.
        for p in 0..self.cfg.num_client_procs {
            for t in 0..tpp {
                self.cores[p as usize].register_worker(WorkerId(p * tpp + t));
            }
        }
        let mut joins = Vec::new();
        for p in 0..self.cfg.num_client_procs {
            for t in 0..tpp {
                let wid = WorkerId(p * tpp + t);
                let slowdown = if self.cfg.stragglers.workers.contains(&wid.0) {
                    self.cfg.stragglers.slowdown
                } else {
                    1.0
                };
                let core = self.cores[p as usize].clone();
                let f = f.clone();
                joins.push((
                    wid,
                    std::thread::Builder::new()
                        .name(format!("worker{}", wid.0))
                        .spawn(move || {
                            let mut ctx = WorkerCtx::new(wid, core, slowdown, num_workers);
                            f(&mut ctx)
                        })
                        .map_err(Error::Io)?,
                ));
            }
        }
        let mut out = Vec::with_capacity(joins.len());
        let mut panic_msg = None;
        for (wid, j) in joins {
            match j.join() {
                Ok(r) => out.push(r),
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "unknown panic".into());
                    panic_msg.get_or_insert(format!("worker {}: {msg}", wid.0));
                }
            }
        }
        match panic_msg {
            Some(m) => Err(Error::WorkerPanic(m)),
            None => Ok(out),
        }
    }

    /// The client core of process `p` (tests / advanced drivers).
    pub fn client(&self, p: ProcId) -> Arc<ClientCore> {
        self.cores[p.0 as usize].clone()
    }

    /// All client cores.
    pub fn clients(&self) -> &[Arc<ClientCore>] {
        &self.cores
    }

    /// Network metrics (message/byte counters).
    pub fn net_metrics(&self) -> Arc<NetMetrics> {
        self.network.metrics()
    }

    /// The event trace recorder.
    pub fn trace(&self) -> Arc<TraceRecorder> {
        self.trace.clone()
    }

    /// Aggregate worker metrics across processes into one summary line.
    pub fn metrics_summary(&self) -> String {
        self.cores
            .iter()
            .map(|c| format!("proc{}: {}", c.proc.0, c.metrics.summary()))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Orderly shutdown: stop flushers (with a final drain), stop ingress
    /// and shard loops, join all threads.
    pub fn shutdown(mut self) -> Result<()> {
        for core in &self.cores {
            core.stop();
        }
        let sender = self.network.sender();
        // Flushers exit on the stop flag; ingress/shards on Shutdown.
        for p in 0..self.cfg.num_client_procs {
            let _ = sender.send(Msg {
                src: NodeId::Coordinator,
                dst: NodeId::Client(ProcId(p)),
                payload: Payload::Shutdown,
            });
        }
        for s in 0..self.cfg.num_server_shards {
            let _ = sender.send(Msg {
                src: NodeId::Coordinator,
                dst: NodeId::Server(ShardId(s)),
                payload: Payload::Shutdown,
            });
        }
        for j in self.io_threads.drain(..) {
            j.join().map_err(|_| Error::Other("io thread panicked".into()))?;
        }
        for j in self.server_threads.drain(..) {
            j.join().map_err(|_| Error::Other("server thread panicked".into()))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::table::{RowId, RowKind, TableId};

    fn small_cfg() -> SystemConfig {
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(2)
            .flush_interval_us(50)
            .wait_timeout_ms(10_000)
            .build()
    }

    fn table(policy: PolicyConfig) -> TableDesc {
        TableDesc {
            id: TableId(0),
            num_rows: 16,
            row_width: 4,
            row_kind: RowKind::Dense,
            policy,
        }
    }

    #[test]
    fn launch_and_shutdown() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        sys.shutdown().unwrap();
    }

    #[test]
    fn bsp_counter_converges_to_total() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        sys.create_table(table(PolicyConfig::Bsp)).unwrap();
        const CLOCKS: u32 = 5;
        sys.run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            for _ in 0..CLOCKS {
                t.inc(RowId(0), 0, 1.0).unwrap();
                ctx.clock().unwrap();
            }
        })
        .unwrap();
        // 4 workers × 5 incs = 20; a fresh reader that advances one more
        // clock must see everything stamped ≤ 5.
        let vals = sys
            .run_workers(move |ctx| {
                for _ in 0..=CLOCKS {
                    ctx.clock().unwrap();
                }
                let t = ctx.table(TableId(0));
                t.get(RowId(0), 0).unwrap()
            })
            .unwrap();
        for v in vals {
            assert_eq!(v, 20.0, "BSP reader must see all 20 increments");
        }
        sys.shutdown().unwrap();
    }

    #[test]
    fn worker_panic_is_reported() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        let err = sys
            .run_workers(|ctx| {
                if ctx.worker_id().0 == 1 {
                    panic!("boom");
                }
            })
            .unwrap_err();
        assert!(matches!(err, Error::WorkerPanic(_)), "{err}");
        sys.shutdown().unwrap();
    }

    #[test]
    fn vap_writers_do_not_deadlock() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        sys.create_table(table(PolicyConfig::Vap { v_thr: 2.0, strong: false })).unwrap();
        sys.run_workers(|ctx| {
            let t = ctx.table(TableId(0));
            for i in 0..100 {
                t.inc(RowId((i % 4) as u64), 0, 1.0).unwrap();
            }
        })
        .unwrap();
        sys.shutdown().unwrap();
    }
}
