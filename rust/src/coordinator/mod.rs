//! The system coordinator: launches shards, client processes and worker
//! threads inside one OS process (the simulated cluster — see DESIGN.md §3
//! for why this substitution preserves the paper's phenomena).
//!
//! Topology (paper Fig 2): `num_server_shards` server threads, each the
//! event loop of a [`crate::server::ServerShard`]; `num_client_procs`
//! client "processes", each a [`crate::client::ClientCore`] with an
//! ingress thread, a flusher thread and `threads_per_proc` application
//! worker threads driven by [`PsSystem::run_workers`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::client::{ClientCore, WorkerCtx};
use crate::comm::msg::{Msg, Payload};
use crate::comm::{Endpoint, Network, Registrar};
use crate::config::SystemConfig;
use crate::error::{Error, Result};
use crate::metrics::{
    self, ApplyPoolMetrics, CoordMetrics, NetMetrics, Registry, ServeHandle, ShardMetrics,
};
use crate::server::{MemPersistence, PersistHandle, ServerShard, ShardOptions, TableRegistry};
use crate::table::TableDesc;
use crate::trace::TraceRecorder;
use crate::types::{NodeId, ProcId, ShardId, WorkerId};

/// A running parameter-server system.
///
/// ```no_run
/// use bapps::prelude::*;
/// let sys = PsSystem::launch(SystemConfig::default()).unwrap();
/// # sys.shutdown().unwrap();
/// ```
pub struct PsSystem {
    cfg: SystemConfig,
    registry: Arc<TableRegistry>,
    cores: Vec<Arc<ClientCore>>,
    trace: Arc<TraceRecorder>,
    network: Network,
    server_threads: Vec<JoinHandle<()>>,
    io_threads: Vec<JoinHandle<()>>,
    /// Failure monitor thread (heartbeats + shard respawn); returns the
    /// join handles of every shard it respawned. `None` when
    /// `heartbeat_interval_us == 0`.
    monitor: Option<JoinHandle<Vec<JoinHandle<()>>>>,
    monitor_stop: Arc<AtomicBool>,
    /// Shared metrics registry every layer records into.
    hub: Arc<Registry>,
    /// Scrape endpoint (when `cfg.metrics_listen` is set).
    serve_handle: Option<ServeHandle>,
}

impl PsSystem {
    /// Launch shards, client cores and their background threads.
    pub fn launch(cfg: SystemConfig) -> Result<Self> {
        cfg.validate()?;
        let hub = Arc::new(Registry::new());
        let network = Network::new_with_metrics(cfg.net.clone(), Arc::new(NetMetrics::new(&hub)));
        let registry = Arc::new(TableRegistry::default());
        let trace = Arc::new(TraceRecorder::with_registry(
            cfg.trace,
            hub.clone(),
            crate::trace::TraceClock::wall(),
            cfg.trace_ring_slots,
        ));

        // Register every endpoint before spawning anything, so no early
        // message can hit an unregistered mailbox.
        let mut shard_eps = Vec::new();
        for s in 0..cfg.num_server_shards {
            shard_eps.push(network.register(NodeId::Server(ShardId(s))));
        }
        let mut client_eps = Vec::new();
        for p in 0..cfg.num_client_procs {
            client_eps.push(network.register(NodeId::Client(ProcId(p))));
        }

        // One durable persistence handle per shard, held by the failure
        // monitor across shard deaths: a respawn recovers from exactly
        // what its predecessor logged (checkpoint + WAL).
        let persists: Vec<PersistHandle> = (0..cfg.num_server_shards)
            .map(|_| Arc::new(MemPersistence::new()) as PersistHandle)
            .collect();
        let mut server_threads = Vec::new();
        for (s, ep) in shard_eps.into_iter().enumerate() {
            let mut opts = ShardOptions::new(persists[s].clone());
            opts.checkpoint_every = cfg.checkpoint_every;
            opts.metrics = ShardMetrics::new(hub.clone(), s as u32);
            opts.apply_threads = cfg.apply_threads;
            // Pool metric names exist only when the pool does (dead-metric
            // lint: a counter that cannot fire must not register).
            opts.pool_metrics =
                (cfg.apply_threads > 1).then(|| ApplyPoolMetrics::new(&hub, s as u32));
            let shard = ServerShard::with_options(
                ShardId(s as u32),
                cfg.num_client_procs,
                registry.clone(),
                network.sender(),
                trace.clone(),
                opts,
            );
            server_threads.push(
                std::thread::Builder::new()
                    .name(format!("shard{s}"))
                    .spawn(move || shard.run(ep))
                    .map_err(Error::Io)?,
            );
        }

        let mut cores = Vec::new();
        let mut io_threads = Vec::new();
        for (p, ep) in client_eps.into_iter().enumerate() {
            let core = Arc::new(ClientCore::new(
                ProcId(p as u32),
                cfg.clone(),
                registry.clone(),
                network.sender(),
                trace.clone(),
                hub.clone(),
            ));
            let ingress = core.clone();
            io_threads.push(
                std::thread::Builder::new()
                    .name(format!("ingress{p}"))
                    .spawn(move || ingress.run_ingress(ep))
                    .map_err(Error::Io)?,
            );
            let flusher = core.clone();
            io_threads.push(
                std::thread::Builder::new()
                    .name(format!("flusher{p}"))
                    .spawn(move || flusher.run_flusher())
                    .map_err(Error::Io)?,
            );
            cores.push(core);
        }

        // Health probe for `GET /healthz`: shard liveness is inferred from
        // each shard's durable incarnation epoch (a respawn bumps it), so
        // the probe works whether or not the failure monitor runs.
        let h_persists = persists.clone();
        let h_hub = hub.clone();
        let num_shards = cfg.num_server_shards;
        let num_procs = cfg.num_client_procs;
        let health: metrics::HealthProbe = Arc::new(move || {
            let epochs: Vec<String> = h_persists
                .iter()
                .map(|p| p.epoch().map(|e| e.to_string()).unwrap_or_else(|_| "-1".into()))
                .collect();
            let snap = h_hub.snapshot();
            format!(
                "{{\"status\":\"ok\",\"shards\":{},\"procs\":{},\"epochs\":[{}],\
                 \"respawns\":{},\"pushes_applied\":{},\"trace_spans_dropped\":{}}}\n",
                num_shards,
                num_procs,
                epochs.join(","),
                snap.counter_sum("coord_shard_respawns_total"),
                snap.counter_sum("shard_pushes_applied_total"),
                snap.counter_sum("trace_spans_dropped_total"),
            )
        });

        // Failure monitor: heartbeats + respawn-from-durable-state. Off
        // by default (`heartbeat_interval_us == 0`).
        let monitor_stop = Arc::new(AtomicBool::new(false));
        let monitor = if cfg.heartbeat_interval_us > 0 {
            let coord_ep = network.register(NodeId::Coordinator);
            let m_cfg = cfg.clone();
            let m_registry = registry.clone();
            let m_trace = trace.clone();
            let m_registrar = network.registrar();
            let m_stop = monitor_stop.clone();
            let m_hub = hub.clone();
            Some(
                std::thread::Builder::new()
                    .name("monitor".into())
                    .spawn(move || {
                        monitor_loop(
                            m_cfg, m_registry, m_trace, m_registrar, persists, coord_ep, m_stop,
                            m_hub,
                        )
                    })
                    .map_err(Error::Io)?,
            )
        } else {
            None
        };

        let serve_handle = match &cfg.metrics_listen {
            Some(addr) => Some(
                metrics::serve_with(
                    hub.clone(),
                    addr,
                    metrics::ServeOpts { trace: Some(trace.clone()), health: Some(health) },
                )
                .map_err(Error::Io)?,
            ),
            None => None,
        };

        Ok(PsSystem {
            cfg,
            registry,
            cores,
            trace,
            network,
            server_threads,
            io_threads,
            monitor,
            monitor_stop,
            hub,
            serve_handle,
        })
    }

    /// System configuration.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Create a table on every shard and client (lazily instantiated on
    /// first access). Must be called before workers touch the table.
    pub fn create_table(&self, desc: TableDesc) -> Result<()> {
        self.registry.insert(desc)
    }

    /// Run one closure on every worker thread (`P = procs × threads`),
    /// collecting their return values in worker-id order. Blocks until all
    /// workers finish; a panicking worker yields `Error::WorkerPanic`.
    pub fn run_workers<F, R>(&self, f: F) -> Result<Vec<R>>
    where
        F: Fn(&mut WorkerCtx) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let f = Arc::new(f);
        let tpp = self.cfg.threads_per_proc;
        let num_workers = self.cfg.num_workers();
        // Register every worker in its process vector clock BEFORE any
        // thread spawns: a fast-starting worker must never advance the
        // process min (and emit ClockNotify promises) while a late sibling
        // is still outside the clock.
        for p in 0..self.cfg.num_client_procs {
            for t in 0..tpp {
                self.cores[p as usize].register_worker(WorkerId(p * tpp + t));
            }
        }
        let mut joins = Vec::new();
        for p in 0..self.cfg.num_client_procs {
            for t in 0..tpp {
                let wid = WorkerId(p * tpp + t);
                let slowdown = if self.cfg.stragglers.workers.contains(&wid.0) {
                    self.cfg.stragglers.slowdown
                } else {
                    1.0
                };
                let core = self.cores[p as usize].clone();
                let f = f.clone();
                joins.push((
                    wid,
                    std::thread::Builder::new()
                        .name(format!("worker{}", wid.0))
                        .spawn(move || {
                            let mut ctx = WorkerCtx::new(wid, core, slowdown, num_workers);
                            f(&mut ctx)
                        })
                        .map_err(Error::Io)?,
                ));
            }
        }
        let mut out = Vec::with_capacity(joins.len());
        let mut panic_msg = None;
        for (wid, j) in joins {
            match j.join() {
                Ok(r) => out.push(r),
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "unknown panic".into());
                    panic_msg.get_or_insert(format!("worker {}: {msg}", wid.0));
                }
            }
        }
        match panic_msg {
            Some(m) => Err(Error::WorkerPanic(m)),
            None => Ok(out),
        }
    }

    /// The client core of process `p` (tests / advanced drivers).
    pub fn client(&self, p: ProcId) -> Arc<ClientCore> {
        self.cores[p.0 as usize].clone()
    }

    /// All client cores.
    pub fn clients(&self) -> &[Arc<ClientCore>] {
        &self.cores
    }

    /// Network metrics (message/byte counters).
    pub fn net_metrics(&self) -> Arc<NetMetrics> {
        self.network.metrics()
    }

    /// The shared metrics registry (scrape it, snapshot it, report it).
    pub fn metrics_registry(&self) -> Arc<Registry> {
        self.hub.clone()
    }

    /// Bound address of the scrape endpoint, when one was requested via
    /// [`SystemConfig::metrics_listen`](crate::config::SystemConfigBuilder::metrics_listen).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.serve_handle.as_ref().map(|h| h.local_addr())
    }

    /// The event trace recorder.
    pub fn trace(&self) -> Arc<TraceRecorder> {
        self.trace.clone()
    }

    /// Aggregate worker metrics across processes into one summary line.
    pub fn metrics_summary(&self) -> String {
        self.cores
            .iter()
            .map(|c| format!("proc{}: {}", c.proc.0, c.metrics.summary()))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Orderly shutdown: stop the failure monitor, stop flushers (with a
    /// final drain), stop ingress and shard loops, join all threads.
    ///
    /// Nothing is swallowed: a Shutdown notification that cannot be
    /// delivered (endpoint already gone — e.g. a shard that died and was
    /// never respawned) and any panicked thread are reported by name; the
    /// first failure becomes the returned error after every thread has
    /// still been joined.
    pub fn shutdown(mut self) -> Result<()> {
        let mut first_err: Option<Error> = None;
        if let Some(h) = self.serve_handle.take() {
            h.shutdown();
        }
        // Monitor first, so it cannot respawn a shard we are stopping.
        self.monitor_stop.store(true, Ordering::Relaxed);
        let mut respawned = Vec::new();
        if let Some(m) = self.monitor.take() {
            match m.join() {
                Ok(handles) => respawned = handles,
                Err(_) => {
                    first_err.get_or_insert(Error::Other("monitor thread panicked".into()));
                }
            }
        }
        for core in &self.cores {
            core.stop();
        }
        let sender = self.network.sender();
        // Flushers exit on the stop flag; ingress/shards on Shutdown.
        for p in 0..self.cfg.num_client_procs {
            if let Err(e) = sender.send(Msg {
                src: NodeId::Coordinator,
                dst: NodeId::Client(ProcId(p)),
                payload: Payload::Shutdown,
            }) {
                first_err.get_or_insert_with(|| Error::Other(format!("notify client {p}: {e}")));
            }
        }
        for s in 0..self.cfg.num_server_shards {
            if let Err(e) = sender.send(Msg {
                src: NodeId::Coordinator,
                dst: NodeId::Server(ShardId(s)),
                payload: Payload::Shutdown,
            }) {
                first_err.get_or_insert_with(|| Error::Other(format!("notify shard {s}: {e}")));
            }
        }
        let mut join_named = |j: JoinHandle<()>, what: &str| {
            let name = j.thread().name().unwrap_or("<unnamed>").to_string();
            if j.join().is_err() {
                first_err.get_or_insert(Error::Other(format!("{what} thread '{name}' panicked")));
            }
        };
        for j in self.io_threads.drain(..) {
            join_named(j, "io");
        }
        for j in self.server_threads.drain(..) {
            join_named(j, "server");
        }
        for j in respawned {
            join_named(j, "respawned server");
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// The coordinator's failure monitor loop: ping every shard on a fixed
/// cadence, declare one dead after `heartbeat_deadline_us` of silence,
/// swap its mailbox and respawn it from its durable checkpoint + WAL. The
/// recovered shard announces itself to every client, which triggers the
/// client resync protocol (epoch bump, overlay retransmission, pull
/// re-issue) — see DESIGN.md §Recovery.
///
/// Returns the join handles of every respawned shard thread so
/// [`PsSystem::shutdown`] can reap them.
fn monitor_loop(
    cfg: SystemConfig,
    registry: Arc<TableRegistry>,
    trace: Arc<TraceRecorder>,
    registrar: Registrar,
    persists: Vec<PersistHandle>,
    ep: Endpoint,
    stop: Arc<AtomicBool>,
    hub: Arc<Registry>,
) -> Vec<JoinHandle<()>> {
    let cm = CoordMetrics::new(&hub);
    let sender = registrar.sender();
    let interval = Duration::from_micros(cfg.heartbeat_interval_us);
    let deadline = Duration::from_micros(cfg.heartbeat_deadline_us);
    let mut last_pong: Vec<Instant> =
        (0..cfg.num_server_shards).map(|_| Instant::now()).collect();
    let mut respawned: Vec<JoinHandle<()>> = Vec::new();
    let mut seq: u64 = 0;
    // Send instant of recent pings, keyed by seq, for pong RTTs.
    let mut ping_sent: std::collections::HashMap<u64, Instant> = std::collections::HashMap::new();
    while !stop.load(Ordering::Relaxed) {
        seq += 1;
        ping_sent.insert(seq, Instant::now());
        if seq > 8 {
            ping_sent.remove(&(seq - 8));
        }
        for s in 0..cfg.num_server_shards {
            // A send failure here is itself a death signal, but the pong
            // deadline is the single arbiter — keep the loop simple.
            let _ = sender.send(Msg {
                src: NodeId::Coordinator,
                dst: NodeId::Server(ShardId(s)),
                payload: Payload::Ping { seq },
            });
        }
        std::thread::sleep(interval);
        while let Some(msg) = ep.try_recv() {
            if let Payload::Pong { shard, seq: pong_seq } = msg.payload {
                if let Some(t0) = ping_sent.get(&pong_seq) {
                    cm.hb_rtt_us.record(t0.elapsed().as_micros() as u64);
                }
                if let Some(t) = last_pong.get_mut(shard.0 as usize) {
                    *t = Instant::now();
                }
            }
        }
        for s in 0..cfg.num_server_shards {
            if last_pong[s as usize].elapsed() <= deadline {
                continue;
            }
            // Dead: swap the mailbox, recover from durable state, respawn.
            cm.hb_misses.inc();
            let node = NodeId::Server(ShardId(s));
            registrar.deregister(node);
            let shard_ep = registrar.register(node);
            let mut opts = ShardOptions::new(persists[s as usize].clone());
            opts.checkpoint_every = cfg.checkpoint_every;
            opts.metrics = ShardMetrics::new(hub.clone(), s);
            opts.apply_threads = cfg.apply_threads;
            // Re-register returns the same counter cells (same name+labels),
            // so respawns keep accumulating rather than resetting.
            opts.pool_metrics = (cfg.apply_threads > 1).then(|| ApplyPoolMetrics::new(&hub, s));
            match ServerShard::recover(
                ShardId(s),
                cfg.num_client_procs,
                registry.clone(),
                registrar.sender(),
                trace.clone(),
                opts,
            ) {
                Ok(shard) => {
                    cm.respawns.inc();
                    let spawn = std::thread::Builder::new()
                        .name(format!("shard{s}-r"))
                        .spawn(move || shard.run(shard_ep));
                    if let Ok(h) = spawn {
                        respawned.push(h);
                    }
                    last_pong[s as usize] = Instant::now();
                }
                Err(_) => {
                    // Recovery failed: leave the shard down; the next tick
                    // retries with the same durable state.
                    registrar.deregister(node);
                }
            }
        }
    }
    respawned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::table::{RowId, RowKind, TableId};

    fn small_cfg() -> SystemConfig {
        SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(2)
            .flush_interval_us(50)
            .wait_timeout_ms(10_000)
            .build()
    }

    fn table(policy: PolicyConfig) -> TableDesc {
        TableDesc {
            id: TableId(0),
            num_rows: 16,
            row_width: 4,
            row_kind: RowKind::Dense,
            policy,
        }
    }

    #[test]
    fn launch_and_shutdown() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        sys.shutdown().unwrap();
    }

    #[test]
    fn bsp_counter_converges_to_total() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        sys.create_table(table(PolicyConfig::Bsp)).unwrap();
        const CLOCKS: u32 = 5;
        sys.run_workers(move |ctx| {
            let t = ctx.table(TableId(0));
            for _ in 0..CLOCKS {
                t.inc(RowId(0), 0, 1.0).unwrap();
                ctx.clock().unwrap();
            }
        })
        .unwrap();
        // 4 workers × 5 incs = 20; a fresh reader that advances one more
        // clock must see everything stamped ≤ 5.
        let vals = sys
            .run_workers(move |ctx| {
                for _ in 0..=CLOCKS {
                    ctx.clock().unwrap();
                }
                let t = ctx.table(TableId(0));
                t.get(RowId(0), 0).unwrap()
            })
            .unwrap();
        for v in vals {
            assert_eq!(v, 20.0, "BSP reader must see all 20 increments");
        }
        sys.shutdown().unwrap();
    }

    #[test]
    fn worker_panic_is_reported() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        let err = sys
            .run_workers(|ctx| {
                if ctx.worker_id().0 == 1 {
                    panic!("boom");
                }
            })
            .unwrap_err();
        assert!(matches!(err, Error::WorkerPanic(_)), "{err}");
        sys.shutdown().unwrap();
    }

    #[test]
    fn pull_from_a_dead_shard_times_out_instead_of_hanging() {
        // No failure monitor: the dead shard stays dead, and a read that
        // needs it must surface WaitTimeout instead of hanging forever.
        let cfg = SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(1)
            .threads_per_proc(1)
            .flush_interval_us(50)
            .wait_timeout_ms(300)
            .build();
        let sys = PsSystem::launch(cfg).unwrap();
        let desc = table(PolicyConfig::Bsp);
        let victim = desc.shard_of(RowId(0), 2);
        sys.create_table(desc).unwrap();
        sys.network
            .sender()
            .send(Msg {
                src: NodeId::Coordinator,
                dst: NodeId::Server(victim),
                payload: Payload::Shutdown,
            })
            .unwrap();
        let results = sys
            .run_workers(|ctx| {
                ctx.clock().unwrap();
                let t = ctx.table(TableId(0));
                t.get(RowId(0), 0)
            })
            .unwrap();
        for r in results {
            let err = r.expect_err("read served by a dead shard must time out");
            assert!(matches!(err, Error::WaitTimeout { .. }), "{err}");
        }
        sys.shutdown().unwrap();
    }

    #[test]
    fn monitor_respawns_a_dead_shard_and_the_system_converges() {
        let cfg = SystemConfig::builder()
            .num_server_shards(2)
            .num_client_procs(2)
            .threads_per_proc(1)
            .flush_interval_us(50)
            .wait_timeout_ms(20_000)
            .heartbeat_interval_us(5_000)
            .heartbeat_deadline_us(100_000)
            .checkpoint_every(4)
            .build();
        let sys = PsSystem::launch(cfg).unwrap();
        let desc = table(PolicyConfig::Bsp);
        let victim = desc.shard_of(RowId(0), 2);
        sys.create_table(desc).unwrap();
        sys.run_workers(|ctx| {
            let t = ctx.table(TableId(0));
            t.inc(RowId(0), 0, 1.0).unwrap();
            ctx.clock().unwrap();
        })
        .unwrap();
        // Kill the shard owning row 0. The monitor must notice the missed
        // heartbeats, respawn it from checkpoint + WAL, and the clients
        // must resync (retransmit unacked batches, re-issue pulls) so the
        // second phase converges on all four increments.
        sys.network
            .sender()
            .send(Msg {
                src: NodeId::Coordinator,
                dst: NodeId::Server(victim),
                payload: Payload::Shutdown,
            })
            .unwrap();
        let vals = sys
            .run_workers(|ctx| {
                let t = ctx.table(TableId(0));
                t.inc(RowId(0), 0, 1.0).unwrap();
                ctx.clock().unwrap();
                ctx.clock().unwrap();
                t.get(RowId(0), 0).unwrap()
            })
            .unwrap();
        for v in vals {
            assert_eq!(v, 4.0, "all four increments must survive the crash");
        }
        sys.shutdown().unwrap();
    }

    #[test]
    fn vap_writers_do_not_deadlock() {
        let sys = PsSystem::launch(small_cfg()).unwrap();
        sys.create_table(table(PolicyConfig::Vap { v_thr: 2.0, strong: false })).unwrap();
        sys.run_workers(|ctx| {
            let t = ctx.table(TableId(0));
            for i in 0..100 {
                t.inc(RowId((i % 4) as u64), 0, 1.0).unwrap();
            }
        })
        .unwrap();
        sys.shutdown().unwrap();
    }
}
