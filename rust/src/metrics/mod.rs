//! Metrics: lock-free counters and histograms for the hot paths.
//!
//! Three metric families:
//! * [`NetMetrics`] — messages/bytes by message kind (network pressure);
//! * [`WorkerMetrics`] — per-worker op counts, block counts and blocked
//!   time under each consistency gate (the cost of consistency, which is
//!   exactly what the paper's models trade against staleness);
//! * [`StalenessHist`] — distribution of observed read staleness (how far
//!   behind the freshest state reads actually were), the empirical
//!   counterpart of the `s` bound.

use std::sync::Mutex;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Network counters by payload kind.
#[derive(Default)]
pub struct NetMetrics {
    sends: Mutex<HashMap<&'static str, u64>>,
    delivers: Mutex<HashMap<&'static str, u64>>,
    bytes: AtomicU64,
}

impl NetMetrics {
    /// Record an outbound message.
    pub fn record_send(&self, kind: &'static str, bytes: usize) {
        *self.sends.lock().unwrap().entry(kind).or_insert(0) += 1;
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record a delivered (post-delay) message.
    pub fn record_deliver(&self, kind: &'static str) {
        *self.delivers.lock().unwrap().entry(kind).or_insert(0) += 1;
    }

    /// Sends of one kind.
    pub fn sends(&self, kind: &str) -> u64 {
        self.sends.lock().unwrap().get(kind).copied().unwrap_or(0)
    }

    /// Total messages sent across kinds.
    pub fn total_sends(&self) -> u64 {
        self.sends.lock().unwrap().values().sum()
    }

    /// Total payload bytes sent.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Snapshot of all send counters.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> =
            self.sends.lock().unwrap().iter().map(|(k, v)| (k.to_string(), *v)).collect();
        v.sort();
        v
    }
}

/// Per-worker operation and blocking counters. All atomic: worker threads
/// bump them on the hot path, reporters read them concurrently.
#[derive(Default, Debug)]
pub struct WorkerMetrics {
    /// `Get` calls served.
    pub gets: AtomicU64,
    /// `Inc` calls applied.
    pub incs: AtomicU64,
    /// `Clock()` calls.
    pub clocks: AtomicU64,
    /// Times a read blocked on the staleness gate (CAP/SSP/CVAP).
    pub read_blocks: AtomicU64,
    /// Nanoseconds spent blocked on reads.
    pub read_block_ns: AtomicU64,
    /// Times a write blocked on the value gate (VAP/CVAP).
    pub write_blocks: AtomicU64,
    /// Nanoseconds spent blocked on writes.
    pub write_block_ns: AtomicU64,
    /// Cache misses that triggered a network pull.
    pub pulls: AtomicU64,
    /// Pulls re-issued by the blocked-reader retry/backoff path.
    pub pull_retries: AtomicU64,
    /// Overlay batches resent after a shard recovery announcement.
    pub pushes_retransmitted: AtomicU64,
}

impl WorkerMetrics {
    /// Record a read block of the given duration.
    pub fn add_read_block(&self, d: Duration) {
        self.read_blocks.fetch_add(1, Ordering::Relaxed);
        self.read_block_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record a write block of the given duration.
    pub fn add_write_block(&self, d: Duration) {
        self.write_blocks.fetch_add(1, Ordering::Relaxed);
        self.write_block_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Compact single-line render for logs.
    pub fn summary(&self) -> String {
        format!(
            "gets={} incs={} clocks={} pulls={} (retries {}, resent {}) read_blocks={} ({:.1} ms) write_blocks={} ({:.1} ms)",
            self.gets.load(Ordering::Relaxed),
            self.incs.load(Ordering::Relaxed),
            self.clocks.load(Ordering::Relaxed),
            self.pulls.load(Ordering::Relaxed),
            self.pull_retries.load(Ordering::Relaxed),
            self.pushes_retransmitted.load(Ordering::Relaxed),
            self.read_blocks.load(Ordering::Relaxed),
            self.read_block_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.write_blocks.load(Ordering::Relaxed),
            self.write_block_ns.load(Ordering::Relaxed) as f64 / 1e6,
        )
    }
}

/// Power-of-two-bucketed histogram of observed read staleness (in clocks).
/// Bucket `i` counts observations with staleness in `[2^(i-1), 2^i)`;
/// bucket 0 counts exact-freshness reads.
pub struct StalenessHist {
    buckets: [AtomicU64; 16],
}

impl Default for StalenessHist {
    fn default() -> Self {
        StalenessHist { buckets: Default::default() }
    }
}

impl StalenessHist {
    /// Record one read that was `staleness` clocks behind the reader.
    pub fn record(&self, staleness: u32) {
        let idx = if staleness == 0 {
            0
        } else {
            (32 - staleness.leading_zeros()).min(15) as usize
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Maximum *bucket upper bound* with any observation — an upper bound
    /// on the worst staleness seen (used to check the `s` guarantee).
    pub fn max_observed_bound(&self) -> u32 {
        for i in (0..16).rev() {
            if self.buckets[i].load(Ordering::Relaxed) > 0 {
                return if i == 0 { 0 } else { 1 << i };
            }
        }
        0
    }

    /// Bucket counts (for reports).
    pub fn snapshot(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_metrics_accumulate() {
        let m = NetMetrics::default();
        m.record_send("push", 100);
        m.record_send("push", 50);
        m.record_send("pull", 10);
        assert_eq!(m.sends("push"), 2);
        assert_eq!(m.total_sends(), 3);
        assert_eq!(m.bytes_sent(), 160);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
    }

    #[test]
    fn worker_metrics_block_accounting() {
        let m = WorkerMetrics::default();
        m.add_read_block(Duration::from_millis(2));
        m.add_write_block(Duration::from_millis(3));
        m.add_write_block(Duration::from_millis(1));
        assert_eq!(m.read_blocks.load(Ordering::Relaxed), 1);
        assert_eq!(m.write_blocks.load(Ordering::Relaxed), 2);
        assert!(m.summary().contains("write_blocks=2"));
    }

    #[test]
    fn staleness_hist_buckets() {
        let h = StalenessHist::default();
        h.record(0);
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(100);
        assert_eq!(h.count(), 5);
        assert!(h.max_observed_bound() >= 100);
        assert!(h.snapshot()[0] == 1);
    }

    #[test]
    fn staleness_hist_zero_only() {
        let h = StalenessHist::default();
        for _ in 0..10 {
            h.record(0);
        }
        assert_eq!(h.max_observed_bound(), 0);
    }
}
