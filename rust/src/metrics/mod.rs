//! Metrics: the unified observability layer.
//!
//! [`registry`] holds the central [`Registry`] — named, typed, labeled,
//! lock-free counters/gauges/histograms with snapshot, Prometheus text
//! and JSON rendering. [`serve`] exposes a stdlib-only HTTP scrape
//! endpoint and a periodic reporter thread for production mode.
//!
//! This module defines the typed metric *families* each layer holds
//! handles to:
//!
//! * [`NetMetrics`] — messages/bytes by wire kind. Fixed per-kind atomic
//!   arrays indexed by [`crate::comm::msg::kind_index`]: the old
//!   `Mutex<HashMap>` took a lock per message on the hottest path in the
//!   system.
//! * [`WorkerMetrics`] — per-process op counts, block counts/times, pull
//!   retries, retransmissions, egress depth/reorders (the cost of
//!   consistency, which is exactly what the paper's models trade
//!   against staleness).
//! * [`StalenessHist`] — distribution of observed read staleness, the
//!   empirical counterpart of the `s` bound.
//! * [`GateMetrics`] — per-policy gate denials and blocked durations.
//!   Registration is capability-gated (no write-gate metrics for BSP,
//!   no read-gate metrics for VAP) and blocked-duration histograms
//!   register lazily on first block, so the dead-metric lint stays
//!   meaningful.
//! * [`ShardMetrics`] — server apply/dedup/fence rates, pull-serve
//!   latency, forwarded-prefix size, WAL/checkpoint durations, replay
//!   lengths, epoch bumps.
//! * [`CoordMetrics`] — heartbeat RTTs, misses, respawns.
//!
//! Metric names follow Prometheus conventions: `<layer>_<what>_total`
//! for counters, `_us`/`_ns` suffix for duration histograms/counters,
//! labels `proc`/`shard`/`policy`/`kind`/`gate`. See DESIGN.md
//! §Observability.

pub mod registry;
pub mod serve;

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::comm::msg::{kind_index, KINDS};
use crate::config::PolicyConfig;

pub use registry::{
    untouched_across, untouched_names_across, Counter, Gauge, Histogram, Registry, Sample,
    SampleValue, Snapshot, HIST_BUCKETS,
};
pub use serve::{
    serve, serve_with, spawn_reporter, HealthProbe, ReporterHandle, ServeHandle, ServeOpts,
};

/// Network counters by wire kind, plus total bytes and the dispatcher's
/// in-flight queue depth. Lock-free: one atomic add per message.
pub struct NetMetrics {
    sends: [Arc<Counter>; KINDS.len()],
    delivers: [Arc<Counter>; KINDS.len()],
    bytes: Arc<Counter>,
    inflight: Arc<Gauge>,
}

impl Default for NetMetrics {
    /// Unregistered instance (tests / callers without a hub): backed by
    /// a private throwaway registry.
    fn default() -> Self {
        NetMetrics::new(&Registry::new())
    }
}

impl NetMetrics {
    /// Register the per-kind arrays on `reg`.
    pub fn new(reg: &Registry) -> Self {
        NetMetrics {
            sends: std::array::from_fn(|i| {
                reg.counter("net_sends_total", "messages sent by kind", &[("kind", KINDS[i])])
            }),
            delivers: std::array::from_fn(|i| {
                reg.counter(
                    "net_delivers_total",
                    "messages delivered (post-delay) by kind",
                    &[("kind", KINDS[i])],
                )
            }),
            bytes: reg.counter("net_bytes_sent_total", "payload bytes sent", &[]),
            inflight: reg.gauge("net_inflight", "messages queued for delivery", &[]),
        }
    }

    /// Record an outbound message.
    pub fn record_send(&self, kind: &str, bytes: usize) {
        self.sends[kind_index(kind)].inc();
        self.bytes.add(bytes as u64);
    }

    /// Record a delivered (post-delay) message.
    pub fn record_deliver(&self, kind: &str) {
        self.delivers[kind_index(kind)].inc();
    }

    /// Record the delivery queue depth.
    pub fn set_inflight(&self, queued: usize) {
        self.inflight.set(queued as f64);
    }

    /// Sends of one kind.
    pub fn sends(&self, kind: &str) -> u64 {
        self.sends[kind_index(kind)].get()
    }

    /// Total messages sent across kinds.
    pub fn total_sends(&self) -> u64 {
        self.sends.iter().map(|c| c.get()).sum()
    }

    /// Total payload bytes sent.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes.get()
    }

    /// Sorted `(kind, count)` pairs for kinds with at least one send.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> = KINDS
            .iter()
            .enumerate()
            .filter(|(i, _)| self.sends[*i].get() > 0)
            .map(|(i, k)| (k.to_string(), self.sends[i].get()))
            .collect();
        v.sort();
        v
    }
}

/// Per-client-process operation and blocking counters. Handles into the
/// registry: worker threads bump them on the hot path, reporters and
/// scrapes read them concurrently.
pub struct WorkerMetrics {
    /// `Get` calls served.
    pub gets: Arc<Counter>,
    /// `Inc` calls applied.
    pub incs: Arc<Counter>,
    /// `Clock()` calls.
    pub clocks: Arc<Counter>,
    /// Times a read blocked on the staleness gate (CAP/SSP/CVAP).
    pub read_blocks: Arc<Counter>,
    /// Nanoseconds spent blocked on reads.
    pub read_block_ns: Arc<Counter>,
    /// Times a write blocked on the value gate (VAP/CVAP).
    pub write_blocks: Arc<Counter>,
    /// Nanoseconds spent blocked on writes.
    pub write_block_ns: Arc<Counter>,
    /// Cache misses that triggered a network pull.
    pub pulls: Arc<Counter>,
    /// Pulls re-issued: blocked-reader retry/backoff and post-recovery
    /// re-issues.
    pub pull_retries: Arc<Counter>,
    /// Overlay batches resent after a shard recovery announcement.
    pub pushes_retransmitted: Arc<Counter>,
    /// Priority-egress reorders: updates shipped ahead of earlier-queued
    /// ones by the magnitude drain order.
    pub egress_reorders: Arc<Counter>,
    /// Unsent egress rows at the last flush.
    pub egress_rows: Arc<Gauge>,
    /// Largest |delta| written by this process (the paper's `u`).
    pub update_magnitude_max: Arc<Gauge>,
}

impl Default for WorkerMetrics {
    fn default() -> Self {
        WorkerMetrics::new(&Registry::new(), 0)
    }
}

impl WorkerMetrics {
    /// Register this process's counters on `reg`.
    pub fn new(reg: &Registry, proc: u32) -> Self {
        let p = proc.to_string();
        let l: &[(&str, &str)] = &[("proc", &p)];
        WorkerMetrics {
            gets: reg.counter("client_gets_total", "Get calls served", l),
            incs: reg.counter("client_incs_total", "Inc calls applied", l),
            clocks: reg.counter("client_clocks_total", "Clock() calls", l),
            read_blocks: reg.counter("client_read_blocks_total", "reads blocked on the gate", l),
            read_block_ns: reg.counter("client_read_blocked_ns_total", "ns blocked on reads", l),
            write_blocks: reg.counter("client_write_blocks_total", "writes blocked on the gate", l),
            write_block_ns: reg.counter("client_write_blocked_ns_total", "ns blocked on writes", l),
            pulls: reg.counter("client_pulls_total", "cache misses that pulled", l),
            pull_retries: reg.counter("client_pull_retries_total", "pulls re-issued", l),
            pushes_retransmitted: reg.counter(
                "client_pushes_retransmitted_total",
                "overlay batches resent after shard recovery",
                l,
            ),
            egress_reorders: reg.counter(
                "client_egress_reorders_total",
                "updates shipped ahead of earlier-queued ones (magnitude priority)",
                l,
            ),
            egress_rows: reg.gauge("client_egress_rows", "unsent egress rows at last flush", l),
            update_magnitude_max: reg.gauge(
                "client_update_magnitude_max",
                "largest |delta| written (the paper's u)",
                l,
            ),
        }
    }

    /// Record a read block of the given duration.
    pub fn add_read_block(&self, d: Duration) {
        self.read_blocks.inc();
        self.read_block_ns.add(d.as_nanos() as u64);
    }

    /// Record a write block of the given duration.
    pub fn add_write_block(&self, d: Duration) {
        self.write_blocks.inc();
        self.write_block_ns.add(d.as_nanos() as u64);
    }

    /// Compact single-line render for logs.
    pub fn summary(&self) -> String {
        format!(
            "gets={} incs={} clocks={} pulls={} (retries {}, resent {}) read_blocks={} ({:.1} ms) write_blocks={} ({:.1} ms)",
            self.gets.get(),
            self.incs.get(),
            self.clocks.get(),
            self.pulls.get(),
            self.pull_retries.get(),
            self.pushes_retransmitted.get(),
            self.read_blocks.get(),
            self.read_block_ns.get() as f64 / 1e6,
            self.write_blocks.get(),
            self.write_block_ns.get() as f64 / 1e6,
        )
    }
}

/// Power-of-two-bucketed histogram of observed read staleness (in
/// clocks). Bucket `i` counts observations in `[2^(i-1), 2^i)`; bucket 0
/// counts exact-freshness reads. Backed by a registry histogram
/// (`client_read_staleness_clocks`), so it also carries the *exact*
/// maximum — what the metrics-vs-oracle cross-check compares.
pub struct StalenessHist {
    hist: Arc<Histogram>,
}

impl Default for StalenessHist {
    fn default() -> Self {
        StalenessHist::new(&Registry::new(), 0)
    }
}

impl StalenessHist {
    /// Register on `reg` for client process `proc`.
    pub fn new(reg: &Registry, proc: u32) -> Self {
        let p = proc.to_string();
        StalenessHist {
            hist: reg.histogram(
                "client_read_staleness_clocks",
                "observed read staleness (reader clock - effective row clock)",
                &[("proc", &p)],
            ),
        }
    }

    /// Record one read that was `staleness` clocks behind the reader.
    pub fn record(&self, staleness: u32) {
        self.hist.record(staleness as u64);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    /// The worst staleness seen — exact, not a bucket bound (used to
    /// check the `s` guarantee).
    pub fn max_observed_bound(&self) -> u32 {
        self.hist.max() as u32
    }

    /// Bucket counts (for reports).
    pub fn snapshot(&self) -> Vec<u64> {
        self.hist.buckets()
    }
}

/// Per-policy consistency-gate metrics. Registration is
/// capability-gated: a policy without a staleness bound registers no
/// read-gate metrics, one without a value bound no write-gate metrics —
/// a metric that *cannot* fire must not exist, or the dead-metric lint
/// would be meaningless. Blocked-duration histograms register lazily on
/// the first actual block for the same reason (the sim's try-paths never
/// block).
pub struct GateMetrics {
    reg: Arc<Registry>,
    policy: String,
    read_denied: Option<Arc<Counter>>,
    write_denied: Option<Arc<Counter>>,
    read_blocked_us: Mutex<Option<Arc<Histogram>>>,
    write_blocked_us: Mutex<Option<Arc<Histogram>>>,
}

impl GateMetrics {
    /// Register the gate counters `policy` can actually hit.
    pub fn new(reg: Arc<Registry>, policy: &PolicyConfig) -> Self {
        let name = policy.name();
        let l: &[(&str, &str)] = &[("policy", &name)];
        let read_denied = policy.staleness().map(|_| {
            reg.counter("client_read_gate_denied_total", "staleness-gate admission failures", l)
        });
        let write_denied = policy.v_thr().map(|_| {
            reg.counter("client_write_gate_denied_total", "value-gate admission failures", l)
        });
        GateMetrics {
            reg,
            policy: name,
            read_denied,
            write_denied,
            read_blocked_us: Mutex::new(None),
            write_blocked_us: Mutex::new(None),
        }
    }

    /// A read failed the staleness gate (denied or about to block).
    pub fn note_read_denied(&self) {
        if let Some(c) = &self.read_denied {
            c.inc();
        }
    }

    /// A write failed the value gate (denied or about to block).
    pub fn note_write_denied(&self) {
        if let Some(c) = &self.write_denied {
            c.inc();
        }
    }

    /// Record a completed read-block episode.
    pub fn record_read_blocked_us(&self, us: u64) {
        let mut h = self.read_blocked_us.lock().unwrap();
        h.get_or_insert_with(|| {
            self.reg.histogram(
                "client_read_gate_blocked_us",
                "duration of read-block episodes",
                &[("policy", &self.policy)],
            )
        })
        .record(us);
    }

    /// Record a completed write-block episode.
    pub fn record_write_blocked_us(&self, us: u64) {
        let mut h = self.write_blocked_us.lock().unwrap();
        h.get_or_insert_with(|| {
            self.reg.histogram(
                "client_write_gate_blocked_us",
                "duration of write-block episodes",
                &[("policy", &self.policy)],
            )
        })
        .record(us);
    }
}

/// Per-shard server metrics: apply pipeline, pull serving, persistence.
#[derive(Clone)]
pub struct ShardMetrics {
    hub: Arc<Registry>,
    /// Push batches applied live (WAL replay excluded — the cross-check
    /// asserts replay does not double-count).
    pub pushes_applied: Arc<Counter>,
    /// Push batches dropped by per-origin dedup.
    pub pushes_deduped: Arc<Counter>,
    /// Push batches fenced for carrying a stale incarnation epoch.
    pub pushes_fenced: Arc<Counter>,
    /// Pull requests answered.
    pub pulls_served: Arc<Counter>,
    /// Pull latency: request arrival → reply send (0 when immediate).
    pub pull_serve_us: Arc<Histogram>,
    /// Batch apply duration: WAL append done → store mutated (covers the
    /// pooled fan-out barrier when `apply_threads > 1`).
    pub apply_us: Arc<Histogram>,
    /// Rows in the forwarded-prefix replica.
    pub fwd_rows: Arc<Gauge>,
    /// WAL records appended.
    pub wal_appends: Arc<Counter>,
    /// WAL append (incl. fsync for file backends) duration.
    pub wal_append_us: Arc<Histogram>,
    /// Checkpoints taken.
    pub checkpoints: Arc<Counter>,
    /// Checkpoint export+write duration.
    pub checkpoint_us: Arc<Histogram>,
    /// WAL records replayed during recoveries.
    pub wal_replayed: Arc<Counter>,
    /// Incarnation epoch bumps (recoveries completed).
    pub epoch_bumps: Arc<Counter>,
}

impl Default for ShardMetrics {
    fn default() -> Self {
        ShardMetrics::new(Arc::new(Registry::new()), 0)
    }
}

impl ShardMetrics {
    /// Register shard `shard`'s metrics on `hub`.
    pub fn new(hub: Arc<Registry>, shard: u32) -> Self {
        let s = shard.to_string();
        let l: &[(&str, &str)] = &[("shard", &s)];
        ShardMetrics {
            pushes_applied: hub.counter(
                "shard_pushes_applied_total",
                "push batches applied live (replay excluded)",
                l,
            ),
            pushes_deduped: hub.counter(
                "shard_pushes_deduped_total",
                "push batches dropped by per-origin dedup",
                l,
            ),
            pushes_fenced: hub.counter(
                "shard_pushes_fenced_total",
                "push batches fenced by incarnation epoch",
                l,
            ),
            pulls_served: hub.counter("shard_pulls_served_total", "pull requests answered", l),
            pull_serve_us: hub.histogram(
                "shard_pull_serve_us",
                "pull latency: arrival to reply (0 = immediate)",
                l,
            ),
            apply_us: hub.histogram("shard_apply_us", "push batch apply duration", l),
            fwd_rows: hub.gauge("shard_fwd_rows", "rows in the forwarded-prefix replica", l),
            wal_appends: hub.counter("shard_wal_appends_total", "WAL records appended", l),
            wal_append_us: hub.histogram("shard_wal_append_us", "WAL append duration", l),
            checkpoints: hub.counter("shard_checkpoints_total", "checkpoints taken", l),
            checkpoint_us: hub.histogram("shard_checkpoint_us", "checkpoint duration", l),
            wal_replayed: hub.counter(
                "shard_wal_replayed_total",
                "WAL records replayed during recovery",
                l,
            ),
            epoch_bumps: hub.counter("shard_epoch_bumps_total", "incarnation epoch bumps", l),
            hub,
        }
    }

    /// Time source for duration measurements (virtual under the sim).
    pub fn now_us(&self) -> u64 {
        self.hub.now_us()
    }
}

/// Apply-pool metrics. Only registered (by the coordinator) when a shard
/// actually runs with `apply_threads > 1` — under the deterministic
/// simulator (always single-threaded apply) these names must not exist,
/// or the dead-metric lint would flag them.
#[derive(Clone)]
pub struct ApplyPoolMetrics {
    /// Push batches fanned across the apply-worker lanes.
    pub batches_fanned: Arc<Counter>,
    /// Stripe write locks found contended on first try (store-level
    /// counter deltas, authoritative + forwarded stores combined).
    pub stripe_contended: Arc<Counter>,
}

impl ApplyPoolMetrics {
    /// Register shard `shard`'s pool counters on `hub`.
    pub fn new(hub: &Registry, shard: u32) -> Self {
        let s = shard.to_string();
        let l: &[(&str, &str)] = &[("shard", &s)];
        ApplyPoolMetrics {
            batches_fanned: hub.counter(
                "shard_apply_fanout_total",
                "push batches fanned across apply-worker lanes",
                l,
            ),
            stripe_contended: hub.counter(
                "shard_apply_stripe_contended_total",
                "stripe write locks found contended on first try",
                l,
            ),
        }
    }
}

/// Coordinator failure-detector metrics. Only registered when the
/// heartbeat monitor is actually running.
#[derive(Clone)]
pub struct CoordMetrics {
    /// Ping → pong round-trip time.
    pub hb_rtt_us: Arc<Histogram>,
    /// Heartbeat deadlines missed (shard declared dead).
    pub hb_misses: Arc<Counter>,
    /// Shards respawned from persisted state.
    pub respawns: Arc<Counter>,
}

impl CoordMetrics {
    /// Register on `reg`.
    pub fn new(reg: &Registry) -> Self {
        CoordMetrics {
            hb_rtt_us: reg.histogram("coord_heartbeat_rtt_us", "ping to pong round trip", &[]),
            hb_misses: reg.counter(
                "coord_heartbeat_misses_total",
                "heartbeat deadlines missed (shard declared dead)",
                &[],
            ),
            respawns: reg.counter(
                "coord_shard_respawns_total",
                "shards respawned from checkpoint + WAL",
                &[],
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_metrics_accumulate() {
        let m = NetMetrics::default();
        m.record_send("push", 100);
        m.record_send("push", 50);
        m.record_send("pull", 10);
        assert_eq!(m.sends("push"), 2);
        assert_eq!(m.total_sends(), 3);
        assert_eq!(m.bytes_sent(), 160);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
    }

    #[test]
    fn net_metrics_cover_every_kind() {
        let reg = Registry::new();
        let m = NetMetrics::new(&reg);
        for k in KINDS {
            m.record_send(k, 1);
            m.record_deliver(k);
        }
        m.set_inflight(3);
        assert_eq!(m.total_sends(), KINDS.len() as u64);
        let snap = reg.snapshot();
        assert_eq!(snap.counter_sum("net_delivers_total"), KINDS.len() as u64);
        assert_eq!(snap.gauge("net_inflight", &[]), Some(3.0));
        assert!(untouched_across([&snap]).is_empty(), "all net cells touched");
    }

    #[test]
    fn worker_metrics_block_accounting() {
        let m = WorkerMetrics::default();
        m.add_read_block(Duration::from_millis(2));
        m.add_write_block(Duration::from_millis(3));
        m.add_write_block(Duration::from_millis(1));
        assert_eq!(m.read_blocks.get(), 1);
        assert_eq!(m.write_blocks.get(), 2);
        assert!(m.summary().contains("write_blocks=2"));
    }

    #[test]
    fn staleness_hist_buckets() {
        let h = StalenessHist::default();
        h.record(0);
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(100);
        assert_eq!(h.count(), 5);
        assert_eq!(h.max_observed_bound(), 100, "max is exact now");
        assert!(h.snapshot()[0] == 1);
    }

    #[test]
    fn staleness_hist_zero_only() {
        let h = StalenessHist::default();
        for _ in 0..10 {
            h.record(0);
        }
        assert_eq!(h.max_observed_bound(), 0);
    }

    #[test]
    fn gate_metrics_are_capability_gated() {
        let reg = Arc::new(Registry::new());
        let bsp = GateMetrics::new(reg.clone(), &PolicyConfig::Bsp);
        let vap = GateMetrics::new(reg.clone(), &PolicyConfig::Vap { v_thr: 1.0, strong: false });
        bsp.note_read_denied();
        bsp.note_write_denied(); // no-op: BSP has no value gate
        vap.note_write_denied();
        vap.note_read_denied(); // no-op: VAP has no staleness bound
        let snap = reg.snapshot();
        assert_eq!(snap.counter_sum("client_read_gate_denied_total"), 1);
        assert_eq!(snap.counter_sum("client_write_gate_denied_total"), 1);
        // VAP registered no read-gate cell at all.
        let vap_cell = snap.counter("client_read_gate_denied_total", &[("policy", "wvap(v=1)")]);
        assert!(vap_cell.is_none());
    }

    #[test]
    fn gate_blocked_histograms_register_lazily() {
        let reg = Arc::new(Registry::new());
        let g = GateMetrics::new(reg.clone(), &PolicyConfig::Ssp { staleness: 1 });
        assert_eq!(reg.snapshot().hist_count("client_read_gate_blocked_us"), 0);
        assert!(reg
            .snapshot()
            .sample("client_read_gate_blocked_us", &[("policy", "ssp(s=1)")])
            .is_none());
        g.record_read_blocked_us(250);
        g.record_read_blocked_us(10);
        let snap = reg.snapshot();
        assert_eq!(snap.hist_count("client_read_gate_blocked_us"), 2);
        assert_eq!(snap.hist_max("client_read_gate_blocked_us"), 250);
    }

    #[test]
    fn shard_and_coord_metrics_register() {
        let reg = Arc::new(Registry::new());
        let sm = ShardMetrics::new(reg.clone(), 3);
        sm.pushes_applied.inc();
        sm.pull_serve_us.record(7);
        let cm = CoordMetrics::new(&reg);
        cm.hb_rtt_us.record(40);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("shard_pushes_applied_total", &[("shard", "3")]), Some(1));
        assert_eq!(snap.hist_max("shard_pull_serve_us"), 7);
        assert_eq!(snap.hist_count("coord_heartbeat_rtt_us"), 1);
    }
}
