//! Stdlib-only metrics exposition: a tiny HTTP scrape endpoint plus a
//! periodic reporter thread (production mode; the sim reads snapshots
//! directly and never starts either).
//!
//! The endpoint speaks just enough HTTP/1.1 for `curl` and a Prometheus
//! scraper: `GET /metrics` returns the text exposition, `GET
//! /metrics.json` the deterministic JSON dump, anything else 404. One
//! request per connection (`Connection: close`), no keep-alive, no TLS.
//!
//! [`serve_with`] additionally wires `GET /trace` (Chrome/Perfetto JSON
//! from the span recorder) and `GET /healthz` (liveness summary from a
//! caller-supplied probe) — both optional, both 404 when unconfigured.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::trace::TraceRecorder;

use super::registry::{Registry, Snapshot};

/// Health probe: returns a small JSON body for `GET /healthz`.
pub type HealthProbe = Arc<dyn Fn() -> String + Send + Sync>;

/// Optional extras for [`serve_with`].
#[derive(Default, Clone)]
pub struct ServeOpts {
    /// Serve `GET /trace` as Chrome/Perfetto JSON from this recorder.
    pub trace: Option<Arc<TraceRecorder>>,
    /// Serve `GET /healthz` from this probe (JSON; probe decides content).
    pub health: Option<HealthProbe>,
}

/// Handle to a running scrape endpoint; dropping it leaks the thread, so
/// call [`ServeHandle::shutdown`].
pub struct ServeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl ServeHandle {
    /// The bound address (useful with a `:0` request).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:9898"` or `"127.0.0.1:0"`) and serve
/// scrapes of `registry` from a background thread.
pub fn serve(registry: Arc<Registry>, addr: &str) -> std::io::Result<ServeHandle> {
    serve_with(registry, addr, ServeOpts::default())
}

/// [`serve`] plus the optional `/trace` and `/healthz` routes.
pub fn serve_with(
    registry: Arc<Registry>,
    addr: &str,
    opts: ServeOpts,
) -> std::io::Result<ServeHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let t_stop = stop.clone();
    let thread = std::thread::Builder::new()
        .name("metrics-serve".into())
        .spawn(move || {
            for stream in listener.incoming().flatten() {
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                // Serve inline: scrapes are rare and tiny.
                let _ = handle_conn(stream, &registry, &opts);
            }
        })?;
    Ok(ServeHandle { addr: local, stop, thread: Some(thread) })
}

fn handle_conn(
    mut stream: TcpStream,
    registry: &Registry,
    opts: &ServeOpts,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let path = req.split_whitespace().nth(1).unwrap_or("/");
    let (status, ctype, body) = match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4",
            registry.snapshot().render_prometheus(),
        ),
        "/metrics.json" => ("200 OK", "application/json", registry.snapshot().render_json()),
        "/trace" => match &opts.trace {
            Some(t) => ("200 OK", "application/json", t.trace_json()),
            None => ("404 Not Found", "text/plain", "tracing not enabled\n".to_string()),
        },
        "/healthz" => match &opts.health {
            Some(probe) => ("200 OK", "application/json", probe()),
            None => ("404 Not Found", "text/plain", "no health probe\n".to_string()),
        },
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

/// Handle to a periodic reporter thread.
pub struct ReporterHandle {
    stop: Arc<(Mutex<bool>, Condvar)>,
    thread: Option<JoinHandle<()>>,
}

impl ReporterHandle {
    /// Stop and join (fires `sink` one final time on the way out).
    pub fn shutdown(mut self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap() = true;
        cvar.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Spawn a thread that snapshots `registry` every `every` and hands the
/// snapshot to `sink` (log line, file dump, …).
pub fn spawn_reporter(
    registry: Arc<Registry>,
    every: Duration,
    mut sink: impl FnMut(&Snapshot) + Send + 'static,
) -> ReporterHandle {
    let stop = Arc::new((Mutex::new(false), Condvar::new()));
    let t_stop = stop.clone();
    let thread = std::thread::Builder::new()
        .name("metrics-reporter".into())
        .spawn(move || {
            let (lock, cvar) = &*t_stop;
            let mut stopped = lock.lock().unwrap();
            loop {
                if *stopped {
                    break;
                }
                let (guard, _) = cvar.wait_timeout(stopped, every).unwrap();
                stopped = guard;
                sink(&registry.snapshot());
            }
        })
        .expect("spawn metrics-reporter");
    ReporterHandle { stop, thread: Some(thread) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrape(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn endpoint_serves_prometheus_and_json() {
        let reg = Arc::new(Registry::new());
        reg.counter("scrape_me_total", "a counter", &[]).add(7);
        let h = serve(reg, "127.0.0.1:0").unwrap();
        let addr = h.local_addr();
        let text = scrape(addr, "/metrics");
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
        assert!(text.contains("scrape_me_total 7"), "{text}");
        let json = scrape(addr, "/metrics.json");
        assert!(json.contains("\"scrape_me_total\""), "{json}");
        let missing = scrape(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        h.shutdown();
    }

    #[test]
    fn trace_and_healthz_routes() {
        let reg = Arc::new(Registry::new());
        let tr = Arc::new(TraceRecorder::new(true));
        tr.sink(crate::trace::SpanNode::Client(crate::types::ProcId(0))).span(
            crate::trace::SpanKind::Batch,
            7,
            10,
            20,
            [0, 0, 0, 0],
        );
        let probe: HealthProbe = Arc::new(|| "{\"ok\":true}".to_string());
        let h = serve_with(
            reg,
            "127.0.0.1:0",
            ServeOpts { trace: Some(tr), health: Some(probe) },
        )
        .unwrap();
        let addr = h.local_addr();
        let trace = scrape(addr, "/trace");
        assert!(trace.starts_with("HTTP/1.1 200 OK"), "{trace}");
        assert!(trace.contains("traceEvents"), "{trace}");
        let health = scrape(addr, "/healthz");
        assert!(health.contains("{\"ok\":true}"), "{health}");
        h.shutdown();

        // Unconfigured routes 404 instead of panicking.
        let h = serve(Arc::new(Registry::new()), "127.0.0.1:0").unwrap();
        let missing = scrape(h.local_addr(), "/trace");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        h.shutdown();
    }

    #[test]
    fn reporter_fires_and_stops() {
        let reg = Arc::new(Registry::new());
        reg.counter("tick_total", "", &[]).inc();
        let seen = Arc::new(Mutex::new(0u32));
        let t_seen = seen.clone();
        let h = spawn_reporter(reg, Duration::from_millis(5), move |snap| {
            assert_eq!(snap.counter("tick_total", &[]), Some(1));
            *t_seen.lock().unwrap() += 1;
        });
        std::thread::sleep(Duration::from_millis(40));
        h.shutdown();
        assert!(*seen.lock().unwrap() >= 1);
    }
}
