//! Central metric registry: named, typed, labeled, lock-free.
//!
//! Every layer of the system registers its counters/gauges/histograms
//! here once (registration takes a mutex; the returned handles are
//! plain `Arc`s over atomics, so the hot paths never lock). A
//! [`Registry::snapshot`] is a consistent-enough point-in-time copy that
//! renders to Prometheus text exposition or a JSON dump.
//!
//! Two design rules keep the simulator deterministic:
//!
//! * **Time is injected.** Durations are measured with
//!   [`Registry::now_us`], which reads either a wall [`Instant`] or, under
//!   the sim, a shared virtual-time cell
//!   ([`Registry::with_virtual_clock`]). Identical seed ⇒ identical
//!   histogram contents, byte for byte.
//! * **Histograms record exact maxima.** Alongside power-of-two buckets
//!   each histogram keeps `max` via `fetch_max`, so the
//!   metrics-vs-oracle cross-checks can assert *equality* against the
//!   independent mirrors instead of bucket-bound inequalities.
//!
//! Every cell also carries a `touched` flag (set on first write), which
//! the dead-metric lint unions across runs: a metric registered but never
//! exercised by the smoke suite is a wiring bug.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Number of histogram buckets: bucket 0 counts exact zeros, bucket `i`
/// (1 ≤ i ≤ 20) counts values in `[2^(i-1), 2^i)`, bucket 21 overflows.
pub const HIST_BUCKETS: usize = 22;

/// Monotonically increasing event counter.
#[derive(Default)]
pub struct Counter {
    hits: AtomicU64,
    touched: AtomicBool,
}

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.hits.fetch_add(n, Ordering::Relaxed);
        self.touched.store(true, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
}

/// Last-write-wins value. Stored as `f64` bits; [`Gauge::set_max`] is
/// only meaningful for non-negative values (IEEE-754 bit order matches
/// numeric order there), which is all this codebase records.
#[derive(Default)]
pub struct Gauge {
    bits: AtomicU64,
    touched: AtomicBool,
}

impl Gauge {
    /// Set the value.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
        self.touched.store(true, Ordering::Relaxed);
    }

    /// Raise the value to at least `v` (`v` must be ≥ 0).
    pub fn set_max(&self, v: f64) {
        debug_assert!(v >= 0.0, "Gauge::set_max is bit-ordered: non-negative only");
        self.bits.fetch_max(v.to_bits(), Ordering::Relaxed);
        self.touched.store(true, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Power-of-two-bucketed histogram of `u64` samples (clocks, µs, …) with
/// exact `sum` and exact `max`.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    touched: AtomicBool,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: Default::default(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            touched: AtomicBool::new(false),
        }
    }
}

impl Histogram {
    /// Bucket index of a sample.
    fn index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.buckets[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.touched.store(true, Ordering::Relaxed);
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of samples.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact maximum sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Bucket counts.
    pub fn buckets(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

enum Cell {
    C(Arc<Counter>),
    G(Arc<Gauge>),
    H(Arc<Histogram>),
}

impl Cell {
    fn kind(&self) -> &'static str {
        match self {
            Cell::C(_) => "counter",
            Cell::G(_) => "gauge",
            Cell::H(_) => "histogram",
        }
    }
}

type Key = (String, Vec<(String, String)>);

struct Inner {
    cells: BTreeMap<Key, Cell>,
    /// Per metric *name*: (type, help). First registration wins.
    help: BTreeMap<String, (&'static str, String)>,
}

/// Where `now_us` comes from: wall time (production) or a shared
/// virtual-time cell the sim scheduler advances (determinism).
enum TimeSource {
    Wall(Instant),
    Virtual(Arc<AtomicU64>),
}

/// The registry. Cheap to share (`Arc<Registry>`); all mutation after
/// registration is on lock-free handles.
pub struct Registry {
    inner: Mutex<Inner>,
    time: TimeSource,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    /// A wall-clock registry (production).
    pub fn new() -> Self {
        Registry {
            inner: Mutex::new(Inner { cells: BTreeMap::new(), help: BTreeMap::new() }),
            time: TimeSource::Wall(Instant::now()),
        }
    }

    /// A registry whose `now_us` reads `clock` (sim: the scheduler stores
    /// virtual time there, making every recorded duration deterministic).
    pub fn with_virtual_clock(clock: Arc<AtomicU64>) -> Self {
        Registry {
            inner: Mutex::new(Inner { cells: BTreeMap::new(), help: BTreeMap::new() }),
            time: TimeSource::Virtual(clock),
        }
    }

    /// Microseconds since an arbitrary epoch (registry creation / virtual
    /// time zero). Only differences are meaningful.
    pub fn now_us(&self) -> u64 {
        match &self.time {
            TimeSource::Wall(start) => start.elapsed().as_micros() as u64,
            TimeSource::Virtual(c) => c.load(Ordering::Relaxed),
        }
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> Key {
        let mut l: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        l.sort();
        (name.to_string(), l)
    }

    /// Get-or-register a counter. Same `(name, labels)` returns the same
    /// handle; a kind clash panics (programmer error).
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let mut inner = self.inner.lock().unwrap();
        inner.help.entry(name.to_string()).or_insert_with(|| ("counter", help.to_string()));
        let cell = inner
            .cells
            .entry(Self::key(name, labels))
            .or_insert_with(|| Cell::C(Arc::new(Counter::default())));
        match cell {
            Cell::C(c) => c.clone(),
            other => panic!("metric {name} registered as {} not counter", other.kind()),
        }
    }

    /// Get-or-register a gauge.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let mut inner = self.inner.lock().unwrap();
        inner.help.entry(name.to_string()).or_insert_with(|| ("gauge", help.to_string()));
        let cell = inner
            .cells
            .entry(Self::key(name, labels))
            .or_insert_with(|| Cell::G(Arc::new(Gauge::default())));
        match cell {
            Cell::G(g) => g.clone(),
            other => panic!("metric {name} registered as {} not gauge", other.kind()),
        }
    }

    /// Get-or-register a histogram.
    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap();
        inner.help.entry(name.to_string()).or_insert_with(|| ("histogram", help.to_string()));
        let cell = inner
            .cells
            .entry(Self::key(name, labels))
            .or_insert_with(|| Cell::H(Arc::new(Histogram::default())));
        match cell {
            Cell::H(h) => h.clone(),
            other => panic!("metric {name} registered as {} not histogram", other.kind()),
        }
    }

    /// Point-in-time copy of every registered metric, sorted by
    /// `(name, labels)` (the `BTreeMap` order), so two snapshots of
    /// identical state render identically.
    pub fn snapshot(&self) -> Snapshot {
        let inner = self.inner.lock().unwrap();
        let samples = inner
            .cells
            .iter()
            .map(|((name, labels), cell)| {
                let (value, touched) = match cell {
                    Cell::C(c) => {
                        (SampleValue::Counter(c.get()), c.touched.load(Ordering::Relaxed))
                    }
                    Cell::G(g) => (SampleValue::Gauge(g.get()), g.touched.load(Ordering::Relaxed)),
                    Cell::H(h) => (
                        SampleValue::Histogram {
                            buckets: h.buckets(),
                            count: h.count(),
                            sum: h.sum(),
                            max: h.max(),
                        },
                        h.touched.load(Ordering::Relaxed),
                    ),
                };
                let help = inner.help.get(name).map(|(_, h)| h.clone()).unwrap_or_default();
                Sample { name: name.clone(), labels: labels.clone(), help, value, touched }
            })
            .collect();
        Snapshot { samples }
    }
}

/// One metric cell at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Metric name (e.g. `net_sends_total`).
    pub name: String,
    /// Sorted label pairs.
    pub labels: Vec<(String, String)>,
    /// Help text.
    pub help: String,
    /// The value.
    pub value: SampleValue,
    /// Was this cell ever written?
    pub touched: bool,
}

/// A snapshotted value.
#[derive(Debug, Clone, PartialEq)]
pub enum SampleValue {
    /// Counter value.
    Counter(u64),
    /// Gauge value.
    Gauge(f64),
    /// Histogram state.
    Histogram {
        /// Bucket counts (see [`HIST_BUCKETS`]).
        buckets: Vec<u64>,
        /// Total samples.
        count: u64,
        /// Sum of samples.
        sum: u64,
        /// Exact maximum sample.
        max: u64,
    },
}

/// Point-in-time registry copy; renders to Prometheus text or JSON.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Snapshot {
    /// Samples sorted by `(name, labels)`.
    pub samples: Vec<Sample>,
}

impl Snapshot {
    /// Look up one sample by exact name + label set.
    pub fn sample(&self, name: &str, labels: &[(&str, &str)]) -> Option<&Sample> {
        let mut want: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        want.sort();
        self.samples.iter().find(|s| s.name == name && s.labels == want)
    }

    /// Counter value at exact name + labels.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        match self.sample(name, labels)?.value {
            SampleValue::Counter(v) => Some(v),
            _ => None,
        }
    }

    /// Sum of a counter across all label sets (0 when unregistered).
    pub fn counter_sum(&self, name: &str) -> u64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| match s.value {
                SampleValue::Counter(v) => v,
                _ => 0,
            })
            .sum()
    }

    /// Gauge value at exact name + labels.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        match self.sample(name, labels)?.value {
            SampleValue::Gauge(v) => Some(v),
            _ => None,
        }
    }

    /// Max of a gauge across all label sets (0.0 when unregistered).
    pub fn gauge_max(&self, name: &str) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| match s.value {
                SampleValue::Gauge(v) => v,
                _ => 0.0,
            })
            .fold(0.0, f64::max)
    }

    /// Exact max of a histogram across all label sets.
    pub fn hist_max(&self, name: &str) -> u64 {
        self.hist_fold(name, |h| h.2)
    }

    /// Total samples of a histogram across all label sets.
    pub fn hist_count(&self, name: &str) -> u64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| match s.value {
                SampleValue::Histogram { count, .. } => count,
                _ => 0,
            })
            .sum()
    }

    /// Sum of samples of a histogram across all label sets.
    pub fn hist_sum(&self, name: &str) -> u64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| match s.value {
                SampleValue::Histogram { sum, .. } => sum,
                _ => 0,
            })
            .sum()
    }

    fn hist_fold(&self, name: &str, pick: impl Fn((u64, u64, u64)) -> u64) -> u64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| match s.value {
                SampleValue::Histogram { count, sum, max, .. } => pick((count, sum, max)),
                _ => 0,
            })
            .fold(0, u64::max)
    }

    /// Prometheus text exposition (v0.0.4).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_name = "";
        for s in &self.samples {
            if s.name != last_name {
                let kind = match s.value {
                    SampleValue::Counter(_) => "counter",
                    SampleValue::Gauge(_) => "gauge",
                    SampleValue::Histogram { .. } => "histogram",
                };
                if !s.help.is_empty() {
                    out.push_str(&format!("# HELP {} {}\n", s.name, s.help));
                }
                out.push_str(&format!("# TYPE {} {kind}\n", s.name));
                last_name = &s.name;
            }
            match &s.value {
                SampleValue::Counter(v) => {
                    out.push_str(&format!("{}{} {v}\n", s.name, prom_labels(&s.labels, None)));
                }
                SampleValue::Gauge(v) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        s.name,
                        prom_labels(&s.labels, None),
                        fmt_f64(*v)
                    ));
                }
                SampleValue::Histogram { buckets, count, sum, .. } => {
                    let mut cum = 0u64;
                    for (i, b) in buckets.iter().enumerate().take(HIST_BUCKETS - 1) {
                        cum += b;
                        let le = ((1u64 << i) - 1).to_string();
                        out.push_str(&format!(
                            "{}_bucket{} {cum}\n",
                            s.name,
                            prom_labels(&s.labels, Some(&le))
                        ));
                    }
                    out.push_str(&format!(
                        "{}_bucket{} {count}\n",
                        s.name,
                        prom_labels(&s.labels, Some("+Inf"))
                    ));
                    out.push_str(&format!(
                        "{}_sum{} {sum}\n",
                        s.name,
                        prom_labels(&s.labels, None)
                    ));
                    out.push_str(&format!(
                        "{}_count{} {count}\n",
                        s.name,
                        prom_labels(&s.labels, None)
                    ));
                }
            }
        }
        out
    }

    /// Deterministic JSON dump: one object per sample, sorted order, no
    /// floating-point surprises (non-finite gauges render as `null`).
    pub fn render_json(&self) -> String {
        let mut out = String::from("{\"metrics\":[\n");
        for (i, s) in self.samples.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            let mut labels = String::from("{");
            for (j, (k, v)) in s.labels.iter().enumerate() {
                if j > 0 {
                    labels.push(',');
                }
                labels.push_str(&format!("{}:{}", json_str(k), json_str(v)));
            }
            labels.push('}');
            let body = match &s.value {
                SampleValue::Counter(v) => format!("\"type\":\"counter\",\"value\":{v}"),
                SampleValue::Gauge(v) => {
                    format!("\"type\":\"gauge\",\"value\":{}", fmt_json_f64(*v))
                }
                SampleValue::Histogram { buckets, count, sum, max } => {
                    let b: Vec<String> = buckets.iter().map(|v| v.to_string()).collect();
                    format!(
                        "\"type\":\"histogram\",\"count\":{count},\"sum\":{sum},\"max\":{max},\
                         \"buckets\":[{}]",
                        b.join(",")
                    )
                }
            };
            out.push_str(&format!(
                "{{\"name\":{},\"labels\":{labels},\"touched\":{},{body}}}",
                json_str(&s.name),
                s.touched
            ));
        }
        out.push_str("\n]}\n");
        out
    }
}

/// Union the `touched` flags across snapshots (possibly from different
/// registries / runs) and return every `(name, labels)` cell that no run
/// ever wrote — the dead-metric lint.
pub fn untouched_across<'a>(snaps: impl IntoIterator<Item = &'a Snapshot>) -> Vec<String> {
    let mut seen: BTreeMap<String, bool> = BTreeMap::new();
    for snap in snaps {
        for s in &snap.samples {
            let key = format!("{}{}", s.name, prom_labels(&s.labels, None));
            let e = seen.entry(key).or_insert(false);
            *e |= s.touched;
        }
    }
    seen.into_iter().filter(|(_, touched)| !touched).map(|(k, _)| k).collect()
}

/// Like [`untouched_across`], but at metric-*name* granularity: a name
/// counts as live if *any* of its label cells was ever written in *any*
/// snapshot. This is the dead-metric lint the smoke suite runs — robust
/// to per-label reachability (e.g. only one of two procs blocking).
pub fn untouched_names_across<'a>(snaps: impl IntoIterator<Item = &'a Snapshot>) -> Vec<String> {
    let mut seen: BTreeMap<String, bool> = BTreeMap::new();
    for snap in snaps {
        for s in &snap.samples {
            let e = seen.entry(s.name.clone()).or_insert(false);
            *e |= s.touched;
        }
    }
    seen.into_iter().filter(|(_, touched)| !touched).map(|(k, _)| k).collect()
}

fn prom_labels(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", prom_escape(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn prom_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn json_str(v: &str) -> String {
    let mut out = String::from("\"");
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "NaN".to_string()
    }
}

fn fmt_json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_are_shared_by_key() {
        let r = Registry::new();
        let a = r.counter("x_total", "help", &[("k", "v")]);
        let b = r.counter("x_total", "help ignored", &[("k", "v")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let other = r.counter("x_total", "", &[("k", "w")]);
        other.inc();
        let snap = r.snapshot();
        assert_eq!(snap.counter("x_total", &[("k", "v")]), Some(3));
        assert_eq!(snap.counter("x_total", &[("k", "w")]), Some(1));
        assert_eq!(snap.counter_sum("x_total"), 4);
        assert_eq!(snap.counter("x_total", &[("k", "missing")]), None);
    }

    #[test]
    fn gauge_set_and_set_max() {
        let r = Registry::new();
        let g = r.gauge("g", "", &[]);
        g.set(1.5);
        assert_eq!(g.get(), 1.5);
        g.set_max(0.5);
        assert_eq!(g.get(), 1.5, "set_max must not lower");
        g.set_max(2.5);
        assert_eq!(g.get(), 2.5);
        assert_eq!(r.snapshot().gauge("g", &[]), Some(2.5));
    }

    #[test]
    fn histogram_buckets_sum_and_exact_max() {
        let r = Registry::new();
        let h = r.histogram("h_us", "", &[]);
        for v in [0u64, 1, 2, 3, 100, 1_000_000, u64::MAX] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.max(), u64::MAX);
        let b = h.buckets();
        assert_eq!(b[0], 1, "zero bucket");
        assert_eq!(b[1], 1, "value 1");
        assert_eq!(b[2], 2, "values 2,3");
        assert_eq!(b[HIST_BUCKETS - 1], 2, "overflow bucket");
        assert_eq!(b.iter().sum::<u64>(), 7);
    }

    #[test]
    #[should_panic(expected = "registered as counter")]
    fn kind_clash_panics() {
        let r = Registry::new();
        let _ = r.counter("clash", "", &[]);
        let _ = r.gauge("clash", "", &[]);
    }

    #[test]
    fn virtual_clock_drives_now_us() {
        let clock = Arc::new(AtomicU64::new(0));
        let r = Registry::with_virtual_clock(clock.clone());
        assert_eq!(r.now_us(), 0);
        clock.store(1234, Ordering::Relaxed);
        assert_eq!(r.now_us(), 1234);
    }

    #[test]
    fn prometheus_render_shape() {
        let r = Registry::new();
        r.counter("a_total", "does things", &[("proc", "0")]).add(5);
        r.gauge("b", "", &[]).set(0.5);
        r.histogram("c_us", "latency", &[]).record(3);
        let text = r.snapshot().render_prometheus();
        assert!(text.contains("# HELP a_total does things"), "{text}");
        assert!(text.contains("# TYPE a_total counter"), "{text}");
        assert!(text.contains("a_total{proc=\"0\"} 5"), "{text}");
        assert!(text.contains("b 0.5"), "{text}");
        assert!(text.contains("# TYPE c_us histogram"), "{text}");
        assert!(text.contains("c_us_bucket{le=\"3\"} 1"), "{text}");
        assert!(text.contains("c_us_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("c_us_sum 3"), "{text}");
        assert!(text.contains("c_us_count 1"), "{text}");
    }

    #[test]
    fn json_render_is_deterministic_and_escaped() {
        let r = Registry::new();
        r.counter("a_total", "", &[("policy", "ssp(s=\"1\")")]).inc();
        r.histogram("h", "", &[]).record(7);
        let s1 = r.snapshot().render_json();
        let s2 = r.snapshot().render_json();
        assert_eq!(s1, s2);
        assert!(s1.contains("\\\"1\\\""), "{s1}");
        assert!(s1.contains("\"max\":7"), "{s1}");
        assert!(s1.starts_with("{\"metrics\":["));
    }

    #[test]
    fn untouched_union_across_snapshots() {
        let r1 = Registry::new();
        r1.counter("live_total", "", &[]);
        r1.counter("dead_total", "", &[]);
        let r2 = Registry::new();
        r2.counter("live_total", "", &[]).inc();
        let (s1, s2) = (r1.snapshot(), r2.snapshot());
        let dead = untouched_across([&s1, &s2]);
        assert_eq!(dead, vec!["dead_total".to_string()]);
    }

    #[test]
    fn untouched_names_collapse_label_cells() {
        let r = Registry::new();
        r.counter("x_total", "", &[("proc", "0")]).inc();
        r.counter("x_total", "", &[("proc", "1")]);
        r.counter("y_total", "", &[("proc", "0")]);
        let snap = r.snapshot();
        assert_eq!(untouched_across([&snap]).len(), 2, "two untouched cells");
        assert_eq!(untouched_names_across([&snap]), vec!["y_total".to_string()]);
    }

    #[test]
    fn hist_helpers_fold_across_labels() {
        let r = Registry::new();
        r.histogram("h", "", &[("g", "a")]).record(10);
        r.histogram("h", "", &[("g", "b")]).record(4);
        let snap = r.snapshot();
        assert_eq!(snap.hist_max("h"), 10);
        assert_eq!(snap.hist_count("h"), 2);
        assert_eq!(snap.hist_sum("h"), 14);
        assert_eq!(snap.gauge_max("h"), 0.0);
    }
}
