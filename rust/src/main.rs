//! `bapps` CLI — launch the parameter server on one of the paper's
//! workloads.
//!
//! ```text
//! bapps table1 [--scale N]
//! bapps lda   --workers 8 --topics 100 --policy vap:8
//! bapps sgd   --workers 4 --policy cvap:2:4 --iters 200
//! bapps mf    --workers 4 --epochs 20
//! bapps transformer --steps 100        # requires `make artifacts`
//! ```
//!
//! Policy specs: `bsp`, `ssp:S`, `cap:S`, `vap:V`, `svap:V`, `cvap:S:V`,
//! `scvap:S:V`, `best-effort`.

use std::collections::HashMap;
use std::sync::Arc;

use bapps::apps::lda::{run_lda, Corpus, LdaConfig, SyntheticCorpusConfig};
use bapps::apps::mf::{run_mf, MfConfig, MfData};
use bapps::apps::sgd::{run_sgd, LogRegData, LogRegDataConfig, SgdConfig};
use bapps::apps::transformer::{train, TrainConfig, TransformerSpec};
use bapps::config::{NetConfig, PolicyConfig, SystemConfig};
use bapps::coordinator::PsSystem;
use bapps::error::{Error, Result};
use bapps::runtime::ComputePool;

const USAGE: &str = "\
bapps — bounded-asynchronous parameter server (Petuum-PS reproduction)

USAGE: bapps <COMMAND> [OPTIONS]

COMMANDS:
  table1        print Table 1 (synthetic 20News corpus statistics)
  lda           LDA topic modeling (the paper's §5 evaluation)
  sgd           distributed SGD logistic regression (Theorem-1 workload)
  mf            matrix factorization
  transformer   end-to-end transformer-LM training (needs `make artifacts`)

COMMON OPTIONS:
  --workers N       total worker threads (default 4)
  --shards N        server shards (default 2)
  --policy SPEC     bsp | ssp:S | cap:S | vap:V | svap:V | cvap:S:V | scvap:S:V | best-effort
                    (default vap:8)
  --lan             simulate the paper's 40GbE LAN instead of an ideal network
  --artifacts DIR   AOT artifacts directory (default 'artifacts')

COMMAND OPTIONS:
  table1:      --scale N (1 = full 20News scale; default 1)
  lda:         --topics N --sweeps N --scale N --xla
  sgd:         --iters N --batch N --n N --d N --xla
  mf:          --m N --n N --rank N --epochs N
  transformer: --steps N --eta F
";

/// Minimal flag parser: `--key value` pairs + bare `--flag` booleans.
struct Args {
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::Other(format!("unexpected argument '{a}'\n\n{USAGE}")))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                kv.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Args { kv, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Other(format!("bad value for --{key}: '{v}'")))
            }
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

fn build_system(args: &Args) -> Result<(PsSystem, PolicyConfig, String)> {
    let workers: u32 = args.get("workers", 4u32)?;
    let shards: u32 = args.get("shards", 2u32)?;
    let policy_spec: String = args.get("policy", "vap:8".to_string())?;
    let policy = PolicyConfig::parse(&policy_spec)?;
    let artifacts: String = args.get("artifacts", "artifacts".to_string())?;
    let procs = if workers >= 2 && workers % 2 == 0 { 2 } else { 1 };
    let cfg = SystemConfig::builder()
        .num_server_shards(shards.max(1))
        .num_client_procs(procs)
        .threads_per_proc((workers / procs).max(1))
        .net(if args.flag("lan") { NetConfig::lan_40gbe() } else { NetConfig::default() })
        .artifacts_dir(artifacts.clone())
        .build();
    let sys = PsSystem::launch(cfg)?;
    Ok((sys, policy, artifacts))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{USAGE}");
        return Err(Error::Other("missing command".into()));
    };
    let args = Args::parse(&argv[1..])?;

    match cmd.as_str() {
        "table1" => {
            let scale: usize = args.get("scale", 1usize)?;
            let cfg = if scale <= 1 {
                SyntheticCorpusConfig::news20()
            } else {
                SyntheticCorpusConfig::news20_scaled(scale)
            };
            let corpus = Corpus::synthetic(&cfg);
            println!("{}", corpus.stats());
        }
        "lda" => {
            let (sys, policy, artifacts) = build_system(&args)?;
            let scale: usize = args.get("scale", 8usize)?;
            let topics: usize = args.get("topics", 100usize)?;
            let sweeps: usize = args.get("sweeps", 5usize)?;
            let xla = args.flag("xla");
            let corpus =
                Arc::new(Corpus::synthetic(&SyntheticCorpusConfig::news20_scaled(scale)));
            println!("corpus:\n{}", corpus.stats());
            let pool = if xla {
                Some(Arc::new(ComputePool::start(&artifacts, 1)?))
            } else {
                None
            };
            let res = run_lda(
                &sys,
                corpus,
                LdaConfig {
                    num_topics: topics,
                    sweeps,
                    policy,
                    use_xla: xla,
                    ..LdaConfig::default()
                },
                pool,
            )?;
            println!(
                "LDA [{}] tokens/s={:.0} wall={:.2}s loglik={:?}",
                policy.name(),
                res.tokens_per_sec,
                res.wall_secs,
                res.loglik_curve
                    .iter()
                    .map(|v| (v * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            );
            println!("{}", sys.metrics_summary());
            sys.shutdown()?;
        }
        "sgd" => {
            let (sys, policy, artifacts) = build_system(&args)?;
            let iters: usize = args.get("iters", 200usize)?;
            let batch: usize = args.get("batch", 32usize)?;
            let n: usize = args.get("n", 8192usize)?;
            let d: usize = args.get("d", 64usize)?;
            let xla = args.flag("xla");
            let data = Arc::new(LogRegData::synthetic(&LogRegDataConfig {
                n,
                d,
                noise: 0.02,
                seed: 13,
            }));
            let pool = if xla {
                Some(Arc::new(ComputePool::start(&artifacts, 1)?))
            } else {
                None
            };
            let res = run_sgd(
                &sys,
                data,
                SgdConfig { iters, batch, policy, use_xla: xla, ..SgdConfig::default() },
                pool,
            )?;
            println!(
                "SGD [{}] loss={:.4} acc={:.3} steps/s={:.0} wall={:.2}s",
                policy.name(),
                res.final_loss,
                res.accuracy,
                res.steps_per_sec,
                res.wall_secs
            );
            sys.shutdown()?;
        }
        "mf" => {
            let (sys, policy, _) = build_system(&args)?;
            let m: usize = args.get("m", 200usize)?;
            let n: usize = args.get("n", 200usize)?;
            let rank: usize = args.get("rank", 8usize)?;
            let epochs: usize = args.get("epochs", 20usize)?;
            let data = Arc::new(MfData::synthetic(m, n, rank.min(4), 0.3, 7));
            let res = run_mf(&sys, data, MfConfig { rank, epochs, policy, ..MfConfig::default() })?;
            println!(
                "MF [{}] rmse={:.4} ratings/s={:.0} curve={:?}",
                policy.name(),
                res.rmse,
                res.ratings_per_sec,
                res.rmse_curve
                    .iter()
                    .map(|v| (v * 1000.0).round() / 1000.0)
                    .collect::<Vec<_>>()
            );
            sys.shutdown()?;
        }
        "transformer" => {
            let (sys, policy, artifacts) = build_system(&args)?;
            let steps: usize = args.get("steps", 100usize)?;
            let eta: f32 = args.get("eta", 0.05f32)?;
            let spec = Arc::new(
                TransformerSpec::load(&artifacts)
                    .map_err(|e| Error::Other(format!("{e} — run `make artifacts` first")))?,
            );
            println!(
                "transformer: {} params, vocab={} d={} layers={}",
                spec.num_params(),
                spec.vocab,
                spec.d_model,
                spec.n_layers
            );
            let pool =
                Arc::new(ComputePool::start(&artifacts, 1)?);
            let res = train(
                &sys,
                spec,
                pool,
                TrainConfig { steps, eta, policy, ..TrainConfig::default() },
            )?;
            println!(
                "transformer [{}] first-loss={:.4} last-loss={:.4} steps/s={:.2}",
                policy.name(),
                res.loss_curve.first().copied().unwrap_or(0.0),
                res.loss_curve.last().copied().unwrap_or(0.0),
                res.steps_per_sec
            );
            sys.shutdown()?;
        }
        "--help" | "-h" | "help" => println!("{USAGE}"),
        other => {
            eprintln!("{USAGE}");
            return Err(Error::Other(format!("unknown command '{other}'")));
        }
    }
    Ok(())
}
