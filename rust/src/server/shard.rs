//! The shard event loop.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::clock::VectorClock;
use crate::comm::msg::{Msg, Payload, PushBatch, ServerPushBatch};
use crate::comm::{Endpoint, NetSender};
use crate::config::PolicyConfig;
use crate::consistency::ConsistencyModel;
use crate::error::{Error, Result};
use crate::metrics::{ApplyPoolMetrics, ShardMetrics};
use crate::table::{RowData, RowId, RowUpdate, TableDesc, TableId, TableStore};
use crate::trace::{Event, SpanKind, SpanNode, SpanSink, TraceCtx, TraceRecorder};
use crate::types::{Clock, NodeId, ProcId, ShardId, WorkerId};

use super::apply::ApplyPool;
use super::persist::{self, MemPersistence, PersistHandle, ShardCheckpoint, TableImage, WalRecord};
use super::visibility::VisibilityTracker;

/// Default number of WAL records folded into a checkpoint.
pub const DEFAULT_CHECKPOINT_EVERY: u64 = 64;

/// Construction options for a shard's durability behaviour.
#[derive(Clone)]
pub struct ShardOptions {
    /// Checkpoint + WAL backend. Share the handle with the supervisor that
    /// will respawn the shard: it is the shard's survivable identity.
    pub persist: PersistHandle,
    /// Fold the WAL into a checkpoint every this many records (0 = never;
    /// the WAL then grows without bound but recovery still works).
    pub checkpoint_every: u64,
    /// Sabotage knob for the simulator's oracle self-test: skip WAL replay
    /// during [`ServerShard::recover`], resurrecting the shard from the
    /// (stale) checkpoint alone. Never set outside tests.
    pub skip_wal_replay: bool,
    /// Metric handles (registered on the system's hub registry by the
    /// coordinator/harness; a throwaway registry by default).
    pub metrics: ShardMetrics,
    /// Apply-path worker threads. `1` (the default, and the only value the
    /// deterministic simulator uses) keeps the sequential inline path; `> 1`
    /// fans each batch's updates across a lane-partitioned [`ApplyPool`].
    /// Either way per-row apply order is the batch slice order, so the
    /// resulting float state is bit-identical.
    pub apply_threads: u32,
    /// Pool-path metric handles. `None` (default) registers nothing — the
    /// coordinator sets this only when `apply_threads > 1`, so the metric
    /// name set is independent of thread count under the simulator's
    /// dead-metric lint.
    pub pool_metrics: Option<ApplyPoolMetrics>,
}

impl ShardOptions {
    /// Options with the default checkpoint cadence.
    pub fn new(persist: PersistHandle) -> Self {
        ShardOptions {
            persist,
            checkpoint_every: DEFAULT_CHECKPOINT_EVERY,
            skip_wal_replay: false,
            metrics: ShardMetrics::default(),
            apply_threads: 1,
            pool_metrics: None,
        }
    }
}

/// Shared registry of table descriptors. The coordinator inserts a
/// descriptor at `create_table`; shards and clients lazily instantiate
/// their per-table state on first access. Registered before any traffic
/// for the table can exist, so lazy init never races a message.
#[derive(Default)]
pub struct TableRegistry {
    tables: RwLock<HashMap<TableId, TableDesc>>,
}

impl TableRegistry {
    /// Register a descriptor (coordinator only). Errors on duplicate id.
    pub fn insert(&self, desc: TableDesc) -> Result<()> {
        desc.validate()?;
        let mut t = self.tables.write().unwrap();
        if t.contains_key(&desc.id) {
            return Err(Error::Config(format!("table {:?} already exists", desc.id)));
        }
        t.insert(desc.id, desc);
        Ok(())
    }

    /// Look up a descriptor.
    pub fn get(&self, id: TableId) -> Result<TableDesc> {
        self.tables.read().unwrap().get(&id).cloned().ok_or(Error::UnknownTable(id))
    }

    /// All registered table ids (sorted).
    pub fn ids(&self) -> Vec<TableId> {
        let mut v: Vec<TableId> = self.tables.read().unwrap().keys().copied().collect();
        v.sort();
        v
    }
}

/// Per-table state held by one shard.
struct ServerTable {
    desc: TableDesc,
    model: ConsistencyModel,
    store: Arc<TableStore>,
    /// Forwarded-prefix replica: batches are applied here at *forward*
    /// time (admission through the release gate), not at arrival. Pull
    /// replies are served from this store, never from `store`: a reply
    /// built from the authoritative store could include a batch whose
    /// `ServerPush` is still in flight to the puller, and the push would
    /// then re-apply those deltas on top of the installed snapshot.
    /// Serving the forwarded prefix makes the composition exactly-once —
    /// on the FIFO shard→client link, every push forwarded before the
    /// reply is delivered before it (already inside the snapshot), and
    /// every push forwarded after it is delivered after (applied once on
    /// top).
    fwd: Arc<TableStore>,
    /// Highest applied batch id per origin (monotone; FIFO links).
    applied_upto: HashMap<ProcId, u64>,
    vis: VisibilityTracker,
}

impl ServerTable {
    fn new(desc: TableDesc, num_procs: u32) -> Self {
        let model = ConsistencyModel::new(desc.policy);
        let store = Arc::new(TableStore::new(desc.row_kind, desc.row_width));
        let fwd = Arc::new(TableStore::new(desc.row_kind, desc.row_width));
        ServerTable {
            desc,
            model,
            store,
            fwd,
            applied_upto: HashMap::new(),
            vis: VisibilityTracker::new(num_procs),
        }
    }
}

/// A deferred pull awaiting the shard's min clock.
struct DeferredPull {
    needed: Clock,
    table: TableId,
    row: RowId,
    worker: WorkerId,
    requester: NodeId,
    /// Arrival time (registry clock) — feeds `shard_pull_serve_us`.
    asked_at: u64,
    /// The request's trace context, echoed in the eventual reply.
    trace: TraceCtx,
}

/// One server shard: owns its partition of every table, applies pushes,
/// answers pulls, forwards server pushes and tracks visibility.
pub struct ServerShard {
    id: ShardId,
    num_client_procs: u32,
    registry: std::sync::Arc<TableRegistry>,
    net: NetSender,
    tables: HashMap<TableId, ServerTable>,
    vclock: VectorClock<ProcId>,
    deferred: Vec<DeferredPull>,
    /// Highest min-clock frontier broadcast so far (monotone).
    last_broadcast: Clock,
    trace: std::sync::Arc<TraceRecorder>,
    /// Incarnation epoch: bumped durably on each recovery. Pushes and clock
    /// notifications stamped with an older epoch are fenced off.
    epoch: u32,
    /// Durable checkpoint + WAL backend.
    persist: PersistHandle,
    /// WAL records appended since the last checkpoint.
    wal_since_cp: u64,
    /// Checkpoint cadence in WAL records (0 = never).
    checkpoint_every: u64,
    /// Sabotage knob (see [`ShardOptions::skip_wal_replay`]).
    skip_wal_replay: bool,
    /// True while replaying the WAL in [`ServerShard::recover`]: state
    /// mutates exactly as live handling would, but sends, trace events,
    /// WAL re-appends and apply/dedup counters are suppressed.
    replaying: bool,
    /// Metric handles (see [`ShardOptions::metrics`]).
    metrics: ShardMetrics,
    /// Lane-partitioned apply workers; `None` keeps the sequential inline
    /// path (see [`ShardOptions::apply_threads`]).
    pool: Option<ApplyPool>,
    /// Pool-path metric handles (coordinator-registered; see
    /// [`ShardOptions::pool_metrics`]).
    pool_metrics: Option<ApplyPoolMetrics>,
    /// Stripe-contention total already exported to `pool_metrics` (the
    /// stores keep running counters; the shard exports deltas).
    contended_seen: u64,
    /// Pool fan-out total already exported to `pool_metrics`.
    fanned_seen: u64,
    /// This shard's span-recording lane.
    sink: SpanSink,
    /// Open `held` spans: admission-denied batches awaiting release,
    /// keyed by batch identity → (trace id, hold start). In-memory only —
    /// a crash loses the open edge, and the span is simply not emitted
    /// (the completeness oracle runs on crash-free schedules).
    held_at: HashMap<(TableId, ProcId, u64), (u64, u64)>,
    /// Open `visible` spans: forwarded batches awaiting their final ack,
    /// keyed by batch identity → (trace id, forward time).
    fanout_at: HashMap<(TableId, ProcId, u64), (u64, u64)>,
}

impl ServerShard {
    /// Build shard state (run it with [`ServerShard::run`] on its own
    /// thread).
    pub fn new(
        id: ShardId,
        num_client_procs: u32,
        registry: std::sync::Arc<TableRegistry>,
        net: NetSender,
    ) -> Self {
        Self::with_trace(
            id,
            num_client_procs,
            registry,
            net,
            std::sync::Arc::new(TraceRecorder::new(false)),
        )
    }

    /// Build shard state with an event-trace recorder attached (and a
    /// private in-memory persistence backend).
    pub fn with_trace(
        id: ShardId,
        num_client_procs: u32,
        registry: std::sync::Arc<TableRegistry>,
        net: NetSender,
        trace: std::sync::Arc<TraceRecorder>,
    ) -> Self {
        let opts = ShardOptions::new(std::sync::Arc::new(MemPersistence::new()));
        Self::with_options(id, num_client_procs, registry, net, trace, opts)
    }

    /// Build shard state with an explicit persistence backend. Share the
    /// backend handle with whoever may later call [`ServerShard::recover`].
    pub fn with_options(
        id: ShardId,
        num_client_procs: u32,
        registry: std::sync::Arc<TableRegistry>,
        net: NetSender,
        trace: std::sync::Arc<TraceRecorder>,
        opts: ShardOptions,
    ) -> Self {
        let vclock = VectorClock::new((0..num_client_procs).map(ProcId));
        let epoch = opts.persist.epoch().unwrap_or(0);
        let pool = (opts.apply_threads > 1).then(|| ApplyPool::new(id.0, opts.apply_threads));
        let sink = trace.sink(SpanNode::Shard(id));
        ServerShard {
            id,
            num_client_procs,
            registry,
            net,
            tables: HashMap::new(),
            vclock,
            deferred: Vec::new(),
            last_broadcast: 0,
            trace,
            epoch,
            persist: opts.persist,
            wal_since_cp: 0,
            checkpoint_every: opts.checkpoint_every,
            skip_wal_replay: opts.skip_wal_replay,
            replaying: false,
            metrics: opts.metrics,
            pool,
            pool_metrics: opts.pool_metrics,
            contended_seen: 0,
            fanned_seen: 0,
            sink,
            held_at: HashMap::new(),
            fanout_at: HashMap::new(),
        }
    }

    /// Rebuild a crashed shard from its persisted state: install the last
    /// checkpoint, replay the WAL suffix through the normal handlers with
    /// sends suppressed (reproducing the exact pre-crash state without
    /// re-emitting traffic), durably bump the incarnation epoch, then
    /// announce the recovery to every client process.
    ///
    /// Replayed mutations cannot violate the consistency gates: the WAL
    /// holds only records that passed the gates when first handled, and
    /// replaying them rebuilds the very state those admission decisions
    /// were based on — recovery is a pure function of the handled prefix.
    pub fn recover(
        id: ShardId,
        num_client_procs: u32,
        registry: std::sync::Arc<TableRegistry>,
        net: NetSender,
        trace: std::sync::Arc<TraceRecorder>,
        opts: ShardOptions,
    ) -> Result<Self> {
        let (cp, wal) = opts.persist.load()?;
        let skip_wal = opts.skip_wal_replay;
        let mut shard = Self::with_options(id, num_client_procs, registry, net, trace, opts);
        if let Some(cp) = cp {
            shard.import_checkpoint(cp);
        }
        if !skip_wal {
            shard.metrics.wal_replayed.add(wal.len() as u64);
            shard.replaying = true;
            for rec in wal {
                match rec {
                    WalRecord::Push(b) => shard.on_push(b),
                    WalRecord::Ack { table, origin, batch_id, by } => {
                        shard.on_push_ack(table, origin, batch_id, by)
                    }
                    WalRecord::Clock { proc, clock } => {
                        let epoch = shard.epoch;
                        shard.on_clock(proc, clock, epoch);
                    }
                }
            }
            shard.replaying = false;
        }
        shard.epoch = shard.persist.bump_epoch()?;
        shard.metrics.epoch_bumps.inc();
        shard.announce_recovery();
        Ok(shard)
    }

    fn import_checkpoint(&mut self, cp: ShardCheckpoint) {
        for (p, c) in cp.vclock {
            self.vclock.advance_to(p, c);
        }
        self.last_broadcast = cp.last_broadcast;
        for img in cp.tables {
            let desc = self.registry.get(img.id).expect("checkpointed table not in registry");
            let mut t = ServerTable::new(desc, self.num_client_procs);
            for (row, data, clock) in img.store {
                t.store.install(row, data, clock);
            }
            for (row, data, clock) in img.fwd {
                t.fwd.install(row, data, clock);
            }
            t.applied_upto = img.applied_upto.into_iter().collect();
            t.vis = VisibilityTracker::from_image(img.vis);
            self.tables.insert(img.id, t);
        }
    }

    /// Image the shard's recovery-relevant state (deterministic order).
    pub fn export_checkpoint(&self) -> ShardCheckpoint {
        let mut tables: Vec<TableImage> = self
            .tables
            .iter()
            .map(|(id, t)| TableImage {
                id: *id,
                store: persist::image_store(&t.store),
                fwd: persist::image_store(&t.fwd),
                applied_upto: persist::image_applied(&t.applied_upto),
                vis: t.vis.export(),
            })
            .collect();
        tables.sort_unstable_by_key(|t| t.id.0);
        let mut vclock: Vec<(ProcId, Clock)> = self.vclock.iter().collect();
        vclock.sort_unstable_by_key(|(p, _)| p.0);
        ShardCheckpoint { vclock, last_broadcast: self.last_broadcast, tables }
    }

    fn announce_recovery(&mut self) {
        // The ShardRecovered broadcast carries the new epoch; on receipt a
        // client resyncs in order — retransmit unechoed batches, re-promise
        // its clock, re-issue in-flight pulls — and only resynced traffic
        // passes the epoch fence.
        for p in 0..self.num_client_procs {
            let _ = self.net.send(Msg {
                src: NodeId::Server(self.id),
                dst: NodeId::Client(ProcId(p)),
                payload: Payload::ShardRecovered { shard: self.id, epoch: self.epoch },
            });
        }
        // Acks sent into the crash window were lost with the old mailbox;
        // re-solicit them. The client re-acks iff it already applied the
        // batch, and the set-based ack tracker absorbs duplicates.
        let mut ids: Vec<TableId> = self.tables.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let probes = self.tables[&id].vis.missing_acks();
            for (origin, batch_id, missing) in probes {
                for p in missing {
                    let _ = self.net.send(Msg {
                        src: NodeId::Server(self.id),
                        dst: NodeId::Client(p),
                        payload: Payload::AckProbe { table: id, origin, batch_id },
                    });
                }
            }
        }
    }

    /// Current incarnation epoch.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Append to the WAL (no-op during replay — the record is already
    /// durable; replay must not re-log it).
    fn log(&mut self, rec: WalRecord) {
        if self.replaying {
            return;
        }
        let t0 = self.metrics.now_us();
        if let Err(e) = self.persist.append(&rec) {
            panic!("shard {}: WAL append failed: {e}", self.id.0);
        }
        self.metrics.wal_appends.inc();
        self.metrics.wal_append_us.record(self.metrics.now_us().saturating_sub(t0));
        self.wal_since_cp += 1;
    }

    fn maybe_checkpoint(&mut self) {
        if self.replaying || self.checkpoint_every == 0 || self.wal_since_cp < self.checkpoint_every
        {
            return;
        }
        let t0 = self.metrics.now_us();
        let cp = self.export_checkpoint();
        if let Err(e) = self.persist.checkpoint(&cp) {
            panic!("shard {}: checkpoint failed: {e}", self.id.0);
        }
        self.metrics.checkpoints.inc();
        self.metrics.checkpoint_us.record(self.metrics.now_us().saturating_sub(t0));
        self.wal_since_cp = 0;
    }

    /// The frontier the shard may safely *assert* to clients: the min
    /// process clock, clamped below any strong-VAP-held batch (whose
    /// updates have been applied but not yet forwarded). For weak models
    /// this is simply the vector-clock minimum.
    fn effective_min(&self) -> Clock {
        let mut m = self.vclock.min_clock();
        for t in self.tables.values() {
            if let Some(held) = t.vis.min_held_clock() {
                m = m.min(held.saturating_sub(1));
            }
        }
        m
    }

    /// Broadcast / service deferred pulls if the effective frontier moved.
    fn after_progress(&mut self) {
        let m = self.effective_min();
        if m <= self.last_broadcast && !(m == 0 && self.last_broadcast == 0) {
            return;
        }
        if m > self.last_broadcast {
            self.last_broadcast = m;
            if !self.replaying {
                self.trace.record(|| Event::Broadcast {
                    at: self.trace.now_us(),
                    shard: self.id.0,
                    clock: m,
                });
                for p in 0..self.num_client_procs {
                    let _ = self.net.send(Msg {
                        src: NodeId::Server(self.id),
                        dst: NodeId::Client(ProcId(p)),
                        payload: Payload::MinClock { shard: self.id, clock: m },
                    });
                }
            }
        }
        // Service deferred pulls that are now satisfiable.
        let (ready, rest): (Vec<_>, Vec<_>) =
            self.deferred.drain(..).partition(|d| d.needed <= m);
        self.deferred = rest;
        for d in ready {
            self.reply_pull(d.requester, d.table, d.row, d.worker, d.asked_at, d.trace);
        }
    }

    /// Shard's node id on the bus.
    pub fn node(&self) -> NodeId {
        NodeId::Server(self.id)
    }

    /// Event loop: process messages until `Shutdown` (or bus close).
    pub fn run(mut self, endpoint: Endpoint) {
        loop {
            match endpoint.recv() {
                Ok(msg) => {
                    if !self.handle(msg) {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    }

    /// Handle one message; returns `false` on shutdown. Public so unit and
    /// property tests can drive a shard synchronously without threads.
    pub fn handle(&mut self, msg: Msg) -> bool {
        match msg.payload {
            Payload::PushUpdates(batch) => self.on_push(batch),
            Payload::PullRow { table, row, needed_clock, worker, trace } => {
                self.on_pull(msg.src, table, row, needed_clock, worker, trace)
            }
            Payload::ClockNotify { proc, clock, epoch } => self.on_clock(proc, clock, epoch),
            Payload::PushAck { table, origin, batch_id, by } => {
                self.on_push_ack(table, origin, batch_id, by)
            }
            Payload::Ping { seq } => self.on_ping(msg.src, seq),
            Payload::Shutdown => return false,
            // Server never receives these:
            Payload::PullReply { .. }
            | Payload::ServerPush(_)
            | Payload::VisibilityAck { .. }
            | Payload::MinClock { .. }
            | Payload::Pong { .. }
            | Payload::AckProbe { .. }
            | Payload::ShardRecovered { .. } => {}
        }
        true
    }

    /// Current min client-process clock at this shard (tests).
    pub fn min_clock(&self) -> Clock {
        self.vclock.min_clock()
    }

    /// Read a row snapshot directly (tests). The returned `Arc` shares the
    /// live copy-on-write row; later applies replace it, they do not mutate
    /// through it.
    pub fn row_snapshot(&self, table: TableId, row: RowId) -> Option<Arc<RowData>> {
        self.tables.get(&table).and_then(|t| t.store.get(row)).map(|sr| sr.data)
    }

    fn table(&mut self, id: TableId) -> &mut ServerTable {
        if !self.tables.contains_key(&id) {
            let desc = self.registry.get(id).expect("message for unregistered table");
            self.tables.insert(id, ServerTable::new(desc, self.num_client_procs));
        }
        self.tables.get_mut(&id).unwrap()
    }

    fn on_push(&mut self, batch: PushBatch) {
        let arrived = self.trace.now_us();
        // Epoch fence: a batch stamped with an older incarnation was sent
        // before its origin resynced with this recovery; accepting it could
        // break per-origin FIFO against a pending retransmission. (Disabled
        // during replay — WAL records carry the epochs they were accepted
        // under.)
        if !self.replaying && batch.epoch < self.epoch {
            self.metrics.pushes_fenced.inc();
            return;
        }
        // Idempotent dedup: at or below the applied frontier means this is a
        // retransmission of a push that survived in the WAL. Dropping it
        // entirely (no re-apply, no re-forward, no re-log) is what makes
        // client retry safe.
        if self
            .tables
            .get(&batch.table)
            .and_then(|t| t.applied_upto.get(&batch.origin))
            .map_or(false, |&p| batch.batch_id <= p)
        {
            if !self.replaying {
                self.metrics.pushes_deduped.inc();
            }
            return;
        }
        let num_procs = self.num_client_procs;
        // Batch identity + trace context outlive the moves below.
        let (origin, batch_id, btrace) = (batch.origin, batch.batch_id, batch.trace);
        let key = [batch.table.0 as u64, origin.0 as u64, batch_id, 0];
        if !self.replaying {
            self.metrics.pushes_applied.inc();
            // One `net` span per *accepted* batch: sealed/sent → applied
            // here. Fenced and deduped arrivals record nothing, so the
            // span count matches the oracle's applied-batch count.
            if !btrace.is_none() {
                self.sink.span(SpanKind::Net, btrace.id, btrace.at_us, arrived, key);
            }
            self.trace.record(|| Event::ShardApplied {
                at: self.trace.now_us(),
                shard: self.id.0,
                origin: batch.origin,
                batch_id: batch.batch_id,
                rows: batch.updates.len(),
            });
        }
        // Write-ahead: log before mutating, so a crash mid-handler replays
        // the whole record rather than losing half of it. The batch clone is
        // an `Arc` bump — the WAL record shares the update list.
        self.log(WalRecord::Push(batch.clone()));
        let batch_table = batch.table;
        // Apply to the authoritative partition (pooled when configured).
        let apply_t0 = self.metrics.now_us();
        let span_t0 = self.trace.now_us();
        let store = Arc::clone(&self.table(batch_table).store);
        self.apply_batch(&store, &batch.updates);
        if !self.replaying {
            self.metrics.apply_us.record(self.metrics.now_us().saturating_sub(apply_t0));
            if !btrace.is_none() {
                self.sink.span(SpanKind::Apply, btrace.id, span_t0, self.trace.now_us(), key);
            }
        }
        // Admit through the (strong-VAP) release gate, then forward. The
        // forwarded-prefix replica advances in lockstep with the forwards
        // so pull replies compose exactly-once with in-flight pushes.
        let (admitted, fwd) = {
            let t = self.table(batch_table);
            t.applied_upto.insert(batch.origin, batch.batch_id);
            t.vis.observe(&batch);
            let admitted = t.vis.admit(&t.model, batch);
            (admitted, Arc::clone(&t.fwd))
        };
        match admitted {
            Some(b) => {
                self.apply_batch(&fwd, &b.updates);
                if !self.replaying {
                    if !btrace.is_none() {
                        self.fanout_at.insert(
                            (batch_table, origin, batch_id),
                            (btrace.id, self.trace.now_us()),
                        );
                    }
                    let min_clock = self.effective_min();
                    Self::forward(&self.net, self.id, num_procs, min_clock, b);
                }
            }
            None => {
                // Strong-VAP hold: open the `held` stage; closed when the
                // release gate lets the batch through.
                if !self.replaying && !btrace.is_none() {
                    self.held_at
                        .insert((batch_table, origin, batch_id), (btrace.id, self.trace.now_us()));
                }
            }
        }
        self.export_pool_metrics();
        let fwd_rows = self.tables[&batch_table].fwd.len();
        self.metrics.fwd_rows.set(fwd_rows as f64);
        self.maybe_checkpoint();
    }

    /// Apply one batch's updates to `store` — fanned across the worker pool
    /// when one is configured, inline otherwise. Both paths apply each row's
    /// updates in slice order (the pool's lanes partition rows), so the
    /// float results are bit-identical.
    fn apply_batch(&self, store: &Arc<TableStore>, updates: &Arc<Vec<(RowId, RowUpdate)>>) {
        match &self.pool {
            Some(pool) => pool.apply(store, updates),
            None => {
                for (row, u) in updates.iter() {
                    store.apply(*row, u);
                }
            }
        }
    }

    /// Export pool-path counters (fan-outs, stripe-contention deltas) to the
    /// coordinator-registered handles, when present.
    fn export_pool_metrics(&mut self) {
        if self.replaying || self.pool_metrics.is_none() {
            return;
        }
        let fanned = self.pool.as_ref().map_or(0, |p| p.batches_fanned());
        let contended: u64 =
            self.tables.values().map(|t| t.store.contended() + t.fwd.contended()).sum();
        let fanned_delta = fanned.saturating_sub(self.fanned_seen);
        let contended_delta = contended.saturating_sub(self.contended_seen);
        self.fanned_seen = fanned;
        self.contended_seen = contended;
        let pm = self.pool_metrics.as_ref().unwrap();
        if fanned_delta > 0 {
            pm.batches_fanned.add(fanned_delta);
        }
        if contended_delta > 0 {
            pm.stripe_contended.add(contended_delta);
        }
    }

    fn forward(net: &NetSender, shard: ShardId, num_procs: u32, min_clock: Clock, b: PushBatch) {
        for p in 0..num_procs {
            // Per-process fan-out shares the origin batch's update list —
            // `P` forwarded pushes, one allocation.
            let push = ServerPushBatch {
                table: b.table,
                origin: b.origin,
                batch_id: b.batch_id,
                updates: Arc::clone(&b.updates),
                min_clock,
                trace: b.trace,
            };
            let _ = net.send(Msg {
                src: NodeId::Server(shard),
                dst: NodeId::Client(ProcId(p)),
                payload: Payload::ServerPush(push),
            });
        }
    }

    fn on_pull(
        &mut self,
        requester: NodeId,
        table: TableId,
        row: RowId,
        needed: Clock,
        worker: WorkerId,
        trace: TraceCtx,
    ) {
        let asked_at = self.metrics.now_us();
        if self.effective_min() >= needed {
            self.reply_pull(requester, table, row, worker, asked_at, trace);
        } else {
            self.deferred.push(DeferredPull {
                needed,
                table,
                row,
                worker,
                requester,
                asked_at,
                trace,
            });
        }
    }

    fn reply_pull(
        &mut self,
        requester: NodeId,
        table: TableId,
        row: RowId,
        worker: WorkerId,
        asked_at: u64,
        trace: TraceCtx,
    ) {
        self.metrics.pulls_served.inc();
        self.metrics.pull_serve_us.record(self.metrics.now_us().saturating_sub(asked_at));
        let min_clock = self.effective_min();
        let t = self.table(table);
        // Serve the *forwarded prefix*, not the authoritative store: see
        // the `ServerTable::fwd` docs for the exactly-once argument. The
        // reply shares the copy-on-write row — no deep copy on the pull
        // hot path.
        let data = t
            .fwd
            .get(row)
            .map(|sr| sr.data)
            .unwrap_or_else(|| Arc::new(RowData::zeros(t.desc.row_kind, t.desc.row_width)));
        let _ = self.net.send(Msg {
            src: NodeId::Server(self.id),
            dst: requester,
            payload: Payload::PullReply { table, row, data, clock: min_clock, worker, trace },
        });
    }

    fn on_clock(&mut self, proc: ProcId, clock: Clock, epoch: u32) {
        // Epoch fence: the promise "no more updates stamped ≤ clock" made
        // before a resync does not hold — retransmissions of older-stamped
        // batches may still be in flight behind it.
        if !self.replaying && epoch < self.epoch {
            return;
        }
        if clock <= self.vclock.get(proc).unwrap_or(0) {
            return; // stale notification: nothing to log or advance
        }
        self.log(WalRecord::Clock { proc, clock });
        if self.vclock.advance_to(proc, clock).is_some() {
            self.after_progress();
        }
        self.maybe_checkpoint();
    }

    fn on_push_ack(&mut self, table: TableId, origin: ProcId, batch_id: u64, by: ProcId) {
        let num_procs = self.num_client_procs;
        let shard = self.id;
        self.log(WalRecord::Ack { table, origin, batch_id, by });
        let final_ack = {
            let t = self.table(table);
            t.vis.ack(origin, batch_id, by)
        };
        if !final_ack {
            self.maybe_checkpoint();
            return;
        }
        let released = {
            let t = self.table(table);
            t.vis.release_ready(&t.model)
        };
        // Globally visible: notify the origin (releases VAP writers).
        if !self.replaying {
            // Close the batch's `visible` stage: forwarded → last ack in.
            if let Some((id, t0)) = self.fanout_at.remove(&(table, origin, batch_id)) {
                self.sink.span(
                    SpanKind::Visible,
                    id,
                    t0,
                    self.trace.now_us(),
                    [table.0 as u64, origin.0 as u64, batch_id, 0],
                );
            }
            let _ = self.net.send(Msg {
                src: NodeId::Server(shard),
                dst: NodeId::Client(origin),
                payload: Payload::VisibilityAck { table, batch_id },
            });
        }
        // Mass released: forward any batches the gate now admits, keeping
        // the forwarded-prefix replica in lockstep.
        {
            let fwd = Arc::clone(&self.table(table).fwd);
            for b in &released {
                self.apply_batch(&fwd, &b.updates);
            }
        }
        if !self.replaying {
            let now = self.trace.now_us();
            let min_clock = self.effective_min();
            for b in released {
                let bkey = (b.table, b.origin, b.batch_id);
                // Close the release-gate hold and open the fan-out stage.
                if let Some((id, t0)) = self.held_at.remove(&bkey) {
                    self.sink.span(
                        SpanKind::Held,
                        id,
                        t0,
                        now,
                        [b.table.0 as u64, b.origin.0 as u64, b.batch_id, 0],
                    );
                }
                if !b.trace.is_none() {
                    self.fanout_at.insert(bkey, (b.trace.id, now));
                }
                Self::forward(&self.net, shard, num_procs, min_clock, b);
            }
        }
        // Releasing holds may raise the broadcastable frontier.
        self.after_progress();
        self.maybe_checkpoint();
    }

    fn on_ping(&mut self, from: NodeId, seq: u64) {
        let _ = self.net.send(Msg {
            src: NodeId::Server(self.id),
            dst: from,
            payload: Payload::Pong { shard: self.id, seq },
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Network;
    use crate::config::NetConfig;
    use crate::table::{RowKind, RowUpdate};
    use std::sync::Arc;

    fn setup(num_procs: u32, policy: PolicyConfig) -> (ServerShard, Vec<Endpoint>, Network) {
        let net = Network::new(NetConfig::default());
        let registry = Arc::new(TableRegistry::default());
        registry
            .insert(TableDesc {
                id: TableId(0),
                num_rows: 64,
                row_width: 4,
                row_kind: RowKind::Dense,
                policy,
            })
            .unwrap();
        let shard = ServerShard::new(ShardId(0), num_procs, registry, net.sender());
        let _sep = net.register(NodeId::Server(ShardId(0)));
        let clients: Vec<Endpoint> =
            (0..num_procs).map(|p| net.register(NodeId::Client(ProcId(p)))).collect();
        (shard, clients, net)
    }

    fn push(origin: u32, id: u64, row: u64, delta: f32) -> Msg {
        Msg {
            src: NodeId::Client(ProcId(origin)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::PushUpdates(PushBatch {
                table: TableId(0),
                origin: ProcId(origin),
                batch_id: id,
                updates: Arc::new(vec![(RowId(row), RowUpdate::single(0, delta))]),
                clock: 1,
                epoch: 0,
                trace: TraceCtx::mint(1, origin as u64, id, 0, 0),
            }),
        }
    }

    fn clock_notify(proc: u32, clock: Clock) -> Msg {
        Msg {
            src: NodeId::Client(ProcId(proc)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::ClockNotify { proc: ProcId(proc), clock, epoch: 0 },
        }
    }

    #[test]
    fn push_applies_and_forwards_to_all_procs() {
        let (mut shard, clients, _net) = setup(2, PolicyConfig::Cap { staleness: 1 });
        shard.handle(push(0, 0, 3, 2.5));
        assert_eq!(shard.row_snapshot(TableId(0), RowId(3)).unwrap().get(0), Some(2.5));
        for c in &clients {
            match c.recv().unwrap().payload {
                Payload::ServerPush(b) => {
                    assert_eq!(b.batch_id, 0);
                    assert_eq!(b.origin, ProcId(0));
                }
                p => panic!("expected ServerPush, got {}", p.kind()),
            }
        }
    }

    #[test]
    fn pull_defers_until_min_clock() {
        let (mut shard, clients, _net) = setup(2, PolicyConfig::Ssp { staleness: 0 });
        // Worker needs clock 1; min clock is 0 → deferred.
        shard.handle(Msg {
            src: NodeId::Client(ProcId(0)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::PullRow {
                table: TableId(0),
                row: RowId(1),
                needed_clock: 1,
                worker: WorkerId(0),
                trace: TraceCtx::NONE,
            },
        });
        assert!(clients[0].try_recv().is_none(), "pull must be deferred");
        // proc 1 reaches clock 1, then proc 0 — min advances.
        shard.handle(clock_notify(1, 1));
        assert!(clients[0].try_recv().is_none());
        shard.handle(clock_notify(0, 1));
        // Client 0 gets MinClock broadcast + the deferred PullReply.
        let mut got_reply = false;
        let mut got_minclock = false;
        while let Some(m) = clients[0].try_recv() {
            match m.payload {
                Payload::PullReply { clock, worker, .. } => {
                    assert_eq!(clock, 1);
                    assert_eq!(worker, WorkerId(0));
                    got_reply = true;
                }
                Payload::MinClock { clock, .. } => {
                    assert_eq!(clock, 1);
                    got_minclock = true;
                }
                _ => {}
            }
        }
        assert!(got_reply && got_minclock);
    }

    #[test]
    fn all_acks_trigger_visibility_to_origin() {
        let (mut shard, clients, _net) = setup(3, PolicyConfig::Vap { v_thr: 8.0, strong: false });
        shard.handle(push(1, 0, 0, 1.0));
        // drain server pushes
        for c in &clients {
            assert!(matches!(c.recv().unwrap().payload, Payload::ServerPush(_)));
        }
        for by in 0..3u32 {
            shard.handle(Msg {
                src: NodeId::Client(ProcId(by)),
                dst: NodeId::Server(ShardId(0)),
                payload: Payload::PushAck {
                    table: TableId(0),
                    origin: ProcId(1),
                    batch_id: 0,
                    by: ProcId(by),
                },
            });
        }
        match clients[1].recv().unwrap().payload {
            Payload::VisibilityAck { batch_id, .. } => assert_eq!(batch_id, 0),
            p => panic!("expected VisibilityAck, got {}", p.kind()),
        }
        assert!(clients[0].try_recv().is_none(), "only origin gets the visibility ack");
    }

    #[test]
    fn strong_vap_defers_forwarding_until_acks() {
        let (mut shard, clients, _net) = setup(2, PolicyConfig::Vap { v_thr: 4.0, strong: true });
        shard.handle(push(0, 0, 7, 3.0));
        shard.handle(push(0, 1, 7, 3.0)); // inflight would be 6 > 4 → held
        // Each client got exactly one ServerPush (batch 0).
        for c in &clients {
            match c.recv().unwrap().payload {
                Payload::ServerPush(b) => assert_eq!(b.batch_id, 0),
                p => panic!("unexpected {}", p.kind()),
            }
            assert!(c.try_recv().is_none(), "batch 1 must be held");
        }
        // Both procs ack batch 0 → batch 1 released.
        for by in 0..2u32 {
            shard.handle(Msg {
                src: NodeId::Client(ProcId(by)),
                dst: NodeId::Server(ShardId(0)),
                payload: Payload::PushAck {
                    table: TableId(0),
                    origin: ProcId(0),
                    batch_id: 0,
                    by: ProcId(by),
                },
            });
        }
        // origin got VisibilityAck(0); everyone now gets ServerPush(1).
        let mut saw_push1 = 0;
        for c in &clients {
            while let Some(m) = c.try_recv() {
                if let Payload::ServerPush(b) = m.payload {
                    assert_eq!(b.batch_id, 1);
                    saw_push1 += 1;
                }
            }
        }
        assert_eq!(saw_push1, 2);
        // Server value reflects both batches regardless of gating.
        assert_eq!(shard.row_snapshot(TableId(0), RowId(7)).unwrap().get(0), Some(6.0));
    }

    #[test]
    fn shutdown_stops_loop() {
        let (mut shard, _clients, _net) = setup(1, PolicyConfig::Bsp);
        assert!(!shard.handle(Msg {
            src: NodeId::Coordinator,
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::Shutdown,
        }));
    }

    #[test]
    fn ping_answers_pong() {
        let (mut shard, _clients, net) = setup(1, PolicyConfig::Bsp);
        let coord = net.register(NodeId::Coordinator);
        shard.handle(Msg {
            src: NodeId::Coordinator,
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::Ping { seq: 42 },
        });
        match coord.recv().unwrap().payload {
            Payload::Pong { shard: s, seq } => {
                assert_eq!(s, ShardId(0));
                assert_eq!(seq, 42);
            }
            p => panic!("expected Pong, got {}", p.kind()),
        }
    }

    fn push_at_epoch(origin: u32, id: u64, row: u64, delta: f32, epoch: u32) -> Msg {
        Msg {
            src: NodeId::Client(ProcId(origin)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::PushUpdates(PushBatch {
                table: TableId(0),
                origin: ProcId(origin),
                batch_id: id,
                updates: Arc::new(vec![(RowId(row), RowUpdate::single(0, delta))]),
                clock: 1,
                epoch,
                trace: TraceCtx::mint(1, origin as u64, id, 0, 0),
            }),
        }
    }

    fn drain(eps: &[Endpoint]) {
        for e in eps {
            while e.try_recv().is_some() {}
        }
    }

    /// Shared-persistence setup for crash/recover tests.
    fn setup_recoverable(
        num_procs: u32,
        policy: PolicyConfig,
        checkpoint_every: u64,
    ) -> (ServerShard, Vec<Endpoint>, Network, Arc<TableRegistry>, ShardOptions) {
        let net = Network::new(NetConfig::default());
        let registry = Arc::new(TableRegistry::default());
        registry
            .insert(TableDesc {
                id: TableId(0),
                num_rows: 64,
                row_width: 4,
                row_kind: RowKind::Dense,
                policy,
            })
            .unwrap();
        let mut opts = ShardOptions::new(Arc::new(MemPersistence::new()));
        opts.checkpoint_every = checkpoint_every;
        let trace = Arc::new(TraceRecorder::new(false));
        let shard = ServerShard::with_options(
            ShardId(0),
            num_procs,
            registry.clone(),
            net.sender(),
            trace,
            opts.clone(),
        );
        let _sep = net.register(NodeId::Server(ShardId(0)));
        let clients: Vec<Endpoint> =
            (0..num_procs).map(|p| net.register(NodeId::Client(ProcId(p)))).collect();
        (shard, clients, net, registry, opts)
    }

    #[test]
    fn recover_replays_wal_and_fences_old_epoch() {
        let (mut shard, clients, net, registry, opts) =
            setup_recoverable(2, PolicyConfig::Cap { staleness: 1 }, 2);
        shard.handle(push(0, 0, 3, 2.5));
        shard.handle(push(0, 1, 3, 1.5));
        shard.handle(push(1, 0, 4, 1.0));
        shard.handle(clock_notify(0, 2));
        shard.handle(clock_notify(1, 1));
        drop(shard); // crash: every in-memory structure is gone
        drain(&clients);

        let trace = Arc::new(TraceRecorder::new(false));
        let mut shard =
            ServerShard::recover(ShardId(0), 2, registry, net.sender(), trace, opts).unwrap();
        assert_eq!(shard.epoch(), 1);
        assert_eq!(shard.min_clock(), 1, "vector clock restored");
        assert_eq!(shard.row_snapshot(TableId(0), RowId(3)).unwrap().get(0), Some(4.0));
        assert_eq!(shard.row_snapshot(TableId(0), RowId(4)).unwrap().get(0), Some(1.0));
        // Every client learns the new epoch before anything else.
        for c in &clients {
            match c.recv().unwrap().payload {
                Payload::ShardRecovered { shard: s, epoch } => {
                    assert_eq!(s, ShardId(0));
                    assert_eq!(epoch, 1);
                }
                p => panic!("expected ShardRecovered first, got {}", p.kind()),
            }
        }
        // A retransmission of an applied batch is dropped, not re-applied.
        shard.handle(push_at_epoch(0, 1, 3, 1.5, 1));
        assert_eq!(shard.row_snapshot(TableId(0), RowId(3)).unwrap().get(0), Some(4.0));
        // A pre-resync batch (old epoch) is fenced even with a fresh id.
        shard.handle(push_at_epoch(0, 7, 3, 9.0, 0));
        assert_eq!(shard.row_snapshot(TableId(0), RowId(3)).unwrap().get(0), Some(4.0));
        // Post-resync traffic at the new epoch flows normally.
        shard.handle(push_at_epoch(0, 7, 3, 1.0, 1));
        assert_eq!(shard.row_snapshot(TableId(0), RowId(3)).unwrap().get(0), Some(5.0));
        // Old-epoch clock promises are fenced; new-epoch ones advance.
        shard.handle(clock_notify(1, 5));
        assert_eq!(shard.min_clock(), 1);
        shard.handle(Msg {
            src: NodeId::Client(ProcId(1)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::ClockNotify { proc: ProcId(1), clock: 5, epoch: 1 },
        });
        assert_eq!(shard.min_clock(), 2);
    }

    #[test]
    fn recovery_probes_only_missing_acks() {
        let (mut shard, clients, net, registry, opts) =
            setup_recoverable(2, PolicyConfig::Vap { v_thr: 8.0, strong: false }, 64);
        shard.handle(push(1, 0, 0, 1.0));
        shard.handle(Msg {
            src: NodeId::Client(ProcId(0)),
            dst: NodeId::Server(ShardId(0)),
            payload: Payload::PushAck {
                table: TableId(0),
                origin: ProcId(1),
                batch_id: 0,
                by: ProcId(0),
            },
        });
        drop(shard);
        drain(&clients);

        let trace = Arc::new(TraceRecorder::new(false));
        let _shard =
            ServerShard::recover(ShardId(0), 2, registry, net.sender(), trace, opts).unwrap();
        // proc 0 already acked: it gets only the recovery announcement.
        assert!(matches!(clients[0].recv().unwrap().payload, Payload::ShardRecovered { .. }));
        assert!(clients[0].try_recv().is_none(), "no probe for an ack the WAL preserved");
        // proc 1's ack is missing: announcement, then a probe.
        assert!(matches!(clients[1].recv().unwrap().payload, Payload::ShardRecovered { .. }));
        match clients[1].recv().unwrap().payload {
            Payload::AckProbe { origin, batch_id, .. } => {
                assert_eq!(origin, ProcId(1));
                assert_eq!(batch_id, 0);
            }
            p => panic!("expected AckProbe, got {}", p.kind()),
        }
    }

    #[test]
    fn skip_wal_replay_sabotage_loses_uncheckpointed_state() {
        let (mut shard, clients, net, registry, mut opts) =
            setup_recoverable(2, PolicyConfig::Cap { staleness: 1 }, 0);
        shard.handle(push(0, 0, 3, 2.5));
        drop(shard);
        drain(&clients);

        opts.skip_wal_replay = true;
        let trace = Arc::new(TraceRecorder::new(false));
        let shard =
            ServerShard::recover(ShardId(0), 2, registry, net.sender(), trace, opts).unwrap();
        // Without replay the push applied before the crash is simply gone —
        // the divergence the simulator's quiescence oracle must catch.
        assert!(shard.row_snapshot(TableId(0), RowId(3)).is_none());
        assert_eq!(shard.epoch(), 1, "epoch still bumps: the bug is silent data loss");
    }
}
