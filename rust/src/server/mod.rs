//! Server shards (paper §4, Fig 2).
//!
//! A shard is one "server process": it owns the hash-partition of every
//! table's rows that maps to it, tracks client-process progress with a
//! vector clock, and services the three communication primitives of §4.3:
//!
//! * **Client Push** — apply a batch of updates, then forward it to every
//!   caching client process (*Server Push*), gated by strong-VAP's
//!   half-synchronized-mass bound when the table's policy requires it;
//! * **Client Pull** — reply with a row snapshot, *deferring* the reply
//!   until the shard's min process clock reaches the freshness the
//!   clock-bounded reader demands;
//! * **Server Push** — forward batches (including an echo to the origin,
//!   which is how origin caches converge) and collect per-process acks;
//!   when every process has acked a batch the shard reports it **globally
//!   visible** to the origin — the event that releases VAP-blocked
//!   writers.
//!
//! The shard is single-threaded over its mailbox: one `Msg` at a time,
//! which makes every per-table mutation trivially atomic — the same
//! design as Petuum PS's server threads.

mod apply;
mod persist;
mod shard;
mod visibility;

pub use apply::ApplyPool;
pub use persist::{
    FilePersistence, MemPersistence, PersistHandle, Persistence, RowImage, ShardCheckpoint,
    TableImage, WalRecord,
};
pub use shard::{ServerShard, ShardOptions, TableRegistry, DEFAULT_CHECKPOINT_EVERY};
pub use visibility::{VisibilityImage, VisibilityTracker};
