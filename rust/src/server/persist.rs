//! Durable shard state: checkpoint + write-ahead log.
//!
//! A shard's recovery contract is *bound-preserving replay*: after a crash,
//! the shard must come back with exactly the state it had after the last
//! message it fully handled, because the consistency gates (SSP clock bound,
//! VAP value bound) are proofs about that state. The shard therefore logs
//! every handled mutation — applied pushes, received acks, accepted clock
//! notifications — to a WAL, and periodically folds the WAL into a full
//! checkpoint (rows of both stores, per-origin applied frontier, the
//! complete visibility tracker, the process vector clock). Recovery installs
//! the checkpoint and replays the WAL suffix through the *same* handlers
//! with sends suppressed, which reproduces the pre-crash state without
//! re-emitting traffic.
//!
//! Replay is idempotent on purpose: a checkpoint written just before a
//! crash may still be followed by WAL records it already covers (the WAL is
//! truncated *after* the checkpoint rename). Re-applying them is harmless —
//! pushes are deduplicated by the per-origin applied frontier, acks are
//! set-based, clock notifications are monotone.
//!
//! Two implementations: [`MemPersistence`] (an `Arc`-shared in-memory store
//! that survives the death of the shard *object*, used by the deterministic
//! simulator) and [`FilePersistence`] (a directory of three files, used by
//! the production path). The file codec is hand-rolled little-endian — the
//! crate builds offline with zero dependencies.

use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::comm::msg::PushBatch;
use crate::error::{Error, Result};
use crate::table::{RowData, RowId, RowUpdate, TableId, TableStore};
use crate::trace::TraceCtx;
use crate::types::{Clock, ProcId};

use super::visibility::VisibilityImage;

/// One durable log record: a mutation the shard fully handled.
#[derive(Debug, Clone)]
pub enum WalRecord {
    /// An applied client push (post epoch-fence, post dedup).
    Push(PushBatch),
    /// One process's ack of a forwarded batch.
    Ack {
        /// Table concerned.
        table: TableId,
        /// Origin process of the acked batch.
        origin: ProcId,
        /// The acked batch id.
        batch_id: u64,
        /// The acking process.
        by: ProcId,
    },
    /// An accepted client clock notification.
    Clock {
        /// Reporting process.
        proc: ProcId,
        /// New min thread clock of that process.
        clock: Clock,
    },
}

/// Materialized rows of one store, `(row, value, row clock)`, sorted by row
/// id for deterministic encoding. Values are `Arc`-shared with the live
/// store (copy-on-write rows), so imaging a table for a checkpoint never
/// deep-copies row data — the codec encodes through the references.
pub type RowImage = Vec<(RowId, Arc<RowData>, Clock)>;

/// Checkpoint of one table's state on one shard.
#[derive(Debug, Clone)]
pub struct TableImage {
    /// The table.
    pub id: TableId,
    /// Authoritative partition rows.
    pub store: RowImage,
    /// Forwarded-prefix replica rows.
    pub fwd: RowImage,
    /// Highest applied batch id per origin, sorted by origin.
    pub applied_upto: Vec<(ProcId, u64)>,
    /// Full visibility-tracker state (ack sets, in-flight mass, held
    /// batches).
    pub vis: VisibilityImage,
}

/// Full checkpoint of a shard: everything recovery needs besides the WAL
/// suffix.
#[derive(Debug, Clone)]
pub struct ShardCheckpoint {
    /// Per-process clocks of the shard's vector clock, sorted by process.
    pub vclock: Vec<(ProcId, Clock)>,
    /// Highest min-clock frontier broadcast before the checkpoint.
    pub last_broadcast: Clock,
    /// Per-table images, sorted by table id.
    pub tables: Vec<TableImage>,
}

/// Durable storage for one shard's recovery state.
///
/// All methods take `&self`: implementations are internally synchronized so
/// a single handle can be shared between the shard and its supervisor.
pub trait Persistence: Send + Sync {
    /// Append one handled-mutation record to the WAL.
    fn append(&self, rec: &WalRecord) -> Result<()>;
    /// Replace the checkpoint and truncate the WAL.
    fn checkpoint(&self, cp: &ShardCheckpoint) -> Result<()>;
    /// Load `(checkpoint, wal suffix)`. A `None` checkpoint with an empty
    /// WAL is a fresh shard.
    fn load(&self) -> Result<(Option<ShardCheckpoint>, Vec<WalRecord>)>;
    /// Current incarnation epoch.
    fn epoch(&self) -> Result<u32>;
    /// Durably bump the incarnation epoch; returns the new value.
    fn bump_epoch(&self) -> Result<u32>;
}

/// Shared handle to a shard's persistence backend.
pub type PersistHandle = Arc<dyn Persistence>;

// ---------------------------------------------------------------------------
// In-memory implementation (simulator).
// ---------------------------------------------------------------------------

#[derive(Default)]
struct MemInner {
    cp: Option<ShardCheckpoint>,
    wal: Vec<WalRecord>,
    epoch: u32,
}

/// In-memory persistence: the handle (shared via `Arc`) survives the death
/// of the shard object, which is exactly the crash model of the
/// deterministic simulator — the process lives, the shard's state dies.
#[derive(Default)]
pub struct MemPersistence {
    inner: Mutex<MemInner>,
}

impl MemPersistence {
    /// Fresh empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of WAL records since the last checkpoint (tests).
    pub fn wal_len(&self) -> usize {
        self.inner.lock().unwrap().wal.len()
    }
}

impl Persistence for MemPersistence {
    fn append(&self, rec: &WalRecord) -> Result<()> {
        self.inner.lock().unwrap().wal.push(rec.clone());
        Ok(())
    }

    fn checkpoint(&self, cp: &ShardCheckpoint) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.cp = Some(cp.clone());
        g.wal.clear();
        Ok(())
    }

    fn load(&self) -> Result<(Option<ShardCheckpoint>, Vec<WalRecord>)> {
        let g = self.inner.lock().unwrap();
        Ok((g.cp.clone(), g.wal.clone()))
    }

    fn epoch(&self) -> Result<u32> {
        Ok(self.inner.lock().unwrap().epoch)
    }

    fn bump_epoch(&self) -> Result<u32> {
        let mut g = self.inner.lock().unwrap();
        g.epoch += 1;
        Ok(g.epoch)
    }
}

// ---------------------------------------------------------------------------
// Binary codec (little-endian, hand-rolled).
// ---------------------------------------------------------------------------

fn corrupt(what: &str) -> Error {
    Error::Other(format!("corrupt persistence data: {what}"))
}

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}
fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(b: &mut Vec<u8>, v: f32) {
    put_u32(b, v.to_bits());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| corrupt("length overflow"))?;
        if end > self.buf.len() {
            return Err(corrupt("unexpected end of buffer"));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
}

fn put_row_data(b: &mut Vec<u8>, d: &RowData) {
    match d {
        RowData::Dense(v) => {
            put_u8(b, 0);
            put_u32(b, v.len() as u32);
            for x in v {
                put_f32(b, *x);
            }
        }
        RowData::Sparse(m) => {
            put_u8(b, 1);
            put_u32(b, m.len() as u32);
            for (c, x) in m {
                put_u32(b, *c);
                put_f32(b, *x);
            }
        }
    }
}

fn get_row_data(r: &mut Reader) -> Result<RowData> {
    match r.u8()? {
        0 => {
            let n = r.u32()? as usize;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(r.f32()?);
            }
            Ok(RowData::Dense(v))
        }
        1 => {
            let n = r.u32()? as usize;
            let mut m = std::collections::BTreeMap::new();
            for _ in 0..n {
                let c = r.u32()?;
                m.insert(c, r.f32()?);
            }
            Ok(RowData::Sparse(m))
        }
        _ => Err(corrupt("row-data tag")),
    }
}

fn put_row_update(b: &mut Vec<u8>, u: &RowUpdate) {
    match u {
        RowUpdate::Dense(v) => {
            put_u8(b, 0);
            put_u32(b, v.len() as u32);
            for x in v {
                put_f32(b, *x);
            }
        }
        RowUpdate::Sparse(pairs) => {
            put_u8(b, 1);
            put_u32(b, pairs.len() as u32);
            for (c, x) in pairs {
                put_u32(b, *c);
                put_f32(b, *x);
            }
        }
    }
}

fn get_row_update(r: &mut Reader) -> Result<RowUpdate> {
    match r.u8()? {
        0 => {
            let n = r.u32()? as usize;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(r.f32()?);
            }
            Ok(RowUpdate::Dense(v))
        }
        1 => {
            let n = r.u32()? as usize;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let c = r.u32()?;
                v.push((c, r.f32()?));
            }
            Ok(RowUpdate::Sparse(v))
        }
        _ => Err(corrupt("row-update tag")),
    }
}

fn put_push_batch(b: &mut Vec<u8>, p: &PushBatch) {
    put_u32(b, p.table.0);
    put_u32(b, p.origin.0);
    put_u64(b, p.batch_id);
    put_u32(b, p.clock);
    put_u32(b, p.epoch);
    // Trace context rides the WAL so replayed batches keep their causal
    // identity (replay itself records no spans, but forwarded state must
    // not lose the id).
    put_u64(b, p.trace.id);
    put_u64(b, p.trace.at_us);
    put_u32(b, p.updates.len() as u32);
    for (row, u) in p.updates.iter() {
        put_u64(b, row.0);
        put_row_update(b, u);
    }
}

fn get_push_batch(r: &mut Reader) -> Result<PushBatch> {
    let table = TableId(r.u32()?);
    let origin = ProcId(r.u32()?);
    let batch_id = r.u64()?;
    let clock = r.u32()?;
    let epoch = r.u32()?;
    let trace = TraceCtx { id: r.u64()?, at_us: r.u64()? };
    let n = r.u32()? as usize;
    let mut updates = Vec::with_capacity(n);
    for _ in 0..n {
        let row = RowId(r.u64()?);
        updates.push((row, get_row_update(r)?));
    }
    Ok(PushBatch { table, origin, batch_id, updates: Arc::new(updates), clock, epoch, trace })
}

/// Encode one WAL record (without framing).
fn put_wal_record(b: &mut Vec<u8>, rec: &WalRecord) {
    match rec {
        WalRecord::Push(p) => {
            put_u8(b, 0);
            put_push_batch(b, p);
        }
        WalRecord::Ack { table, origin, batch_id, by } => {
            put_u8(b, 1);
            put_u32(b, table.0);
            put_u32(b, origin.0);
            put_u64(b, *batch_id);
            put_u32(b, by.0);
        }
        WalRecord::Clock { proc, clock } => {
            put_u8(b, 2);
            put_u32(b, proc.0);
            put_u32(b, *clock);
        }
    }
}

fn get_wal_record(r: &mut Reader) -> Result<WalRecord> {
    match r.u8()? {
        0 => Ok(WalRecord::Push(get_push_batch(r)?)),
        1 => {
            let table = TableId(r.u32()?);
            let origin = ProcId(r.u32()?);
            let batch_id = r.u64()?;
            let by = ProcId(r.u32()?);
            Ok(WalRecord::Ack { table, origin, batch_id, by })
        }
        2 => {
            let proc = ProcId(r.u32()?);
            let clock = r.u32()?;
            Ok(WalRecord::Clock { proc, clock })
        }
        _ => Err(corrupt("wal-record tag")),
    }
}

fn put_row_image(b: &mut Vec<u8>, rows: &RowImage) {
    put_u32(b, rows.len() as u32);
    for (row, data, clock) in rows {
        put_u64(b, row.0);
        put_row_data(b, data);
        put_u32(b, *clock);
    }
}

fn get_row_image(r: &mut Reader) -> Result<RowImage> {
    let n = r.u32()? as usize;
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let row = RowId(r.u64()?);
        let data = Arc::new(get_row_data(r)?);
        rows.push((row, data, r.u32()?));
    }
    Ok(rows)
}

fn put_vis(b: &mut Vec<u8>, v: &VisibilityImage) {
    put_u32(b, v.num_procs);
    put_u32(b, v.pending.len() as u32);
    for (o, id, acked) in &v.pending {
        put_u32(b, o.0);
        put_u64(b, *id);
        put_u32(b, acked.len() as u32);
        for p in acked {
            put_u32(b, p.0);
        }
    }
    put_u32(b, v.inflight.len() as u32);
    for ((row, col), m) in &v.inflight {
        put_u64(b, row.0);
        put_u32(b, *col);
        put_f32(b, *m);
    }
    put_u32(b, v.batch_mass.len() as u32);
    for (o, id, masses) in &v.batch_mass {
        put_u32(b, o.0);
        put_u64(b, *id);
        put_u32(b, masses.len() as u32);
        for ((row, col), m) in masses {
            put_u64(b, row.0);
            put_u32(b, *col);
            put_f32(b, *m);
        }
    }
    put_u32(b, v.held.len() as u32);
    for (o, q) in &v.held {
        put_u32(b, o.0);
        put_u32(b, q.len() as u32);
        for p in q {
            put_push_batch(b, p);
        }
    }
    put_f32(b, v.u_obs);
}

fn get_vis(r: &mut Reader) -> Result<VisibilityImage> {
    let num_procs = r.u32()?;
    let n = r.u32()? as usize;
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let o = ProcId(r.u32()?);
        let id = r.u64()?;
        let k = r.u32()? as usize;
        let mut acked = Vec::with_capacity(k);
        for _ in 0..k {
            acked.push(ProcId(r.u32()?));
        }
        pending.push((o, id, acked));
    }
    let n = r.u32()? as usize;
    let mut inflight = Vec::with_capacity(n);
    for _ in 0..n {
        let row = RowId(r.u64()?);
        let col = r.u32()?;
        inflight.push(((row, col), r.f32()?));
    }
    let n = r.u32()? as usize;
    let mut batch_mass = Vec::with_capacity(n);
    for _ in 0..n {
        let o = ProcId(r.u32()?);
        let id = r.u64()?;
        let k = r.u32()? as usize;
        let mut masses = Vec::with_capacity(k);
        for _ in 0..k {
            let row = RowId(r.u64()?);
            let col = r.u32()?;
            masses.push(((row, col), r.f32()?));
        }
        batch_mass.push((o, id, masses));
    }
    let n = r.u32()? as usize;
    let mut held = Vec::with_capacity(n);
    for _ in 0..n {
        let o = ProcId(r.u32()?);
        let k = r.u32()? as usize;
        let mut q = Vec::with_capacity(k);
        for _ in 0..k {
            q.push(get_push_batch(r)?);
        }
        held.push((o, q));
    }
    let u_obs = r.f32()?;
    Ok(VisibilityImage { num_procs, pending, inflight, batch_mass, held, u_obs })
}

/// File magic guarding the checkpoint codec version.
const CP_MAGIC: &[u8; 8] = b"BAPPSCP1";

fn encode_checkpoint(cp: &ShardCheckpoint) -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(CP_MAGIC);
    put_u32(&mut b, cp.vclock.len() as u32);
    for (p, c) in &cp.vclock {
        put_u32(&mut b, p.0);
        put_u32(&mut b, *c);
    }
    put_u32(&mut b, cp.last_broadcast);
    put_u32(&mut b, cp.tables.len() as u32);
    for t in &cp.tables {
        put_u32(&mut b, t.id.0);
        put_row_image(&mut b, &t.store);
        put_row_image(&mut b, &t.fwd);
        put_u32(&mut b, t.applied_upto.len() as u32);
        for (p, id) in &t.applied_upto {
            put_u32(&mut b, p.0);
            put_u64(&mut b, *id);
        }
        put_vis(&mut b, &t.vis);
    }
    b
}

fn decode_checkpoint(buf: &[u8]) -> Result<ShardCheckpoint> {
    let mut r = Reader::new(buf);
    if r.take(8)? != CP_MAGIC {
        return Err(corrupt("checkpoint magic"));
    }
    let n = r.u32()? as usize;
    let mut vclock = Vec::with_capacity(n);
    for _ in 0..n {
        let p = ProcId(r.u32()?);
        vclock.push((p, r.u32()?));
    }
    let last_broadcast = r.u32()?;
    let n = r.u32()? as usize;
    let mut tables = Vec::with_capacity(n);
    for _ in 0..n {
        let id = TableId(r.u32()?);
        let store = get_row_image(&mut r)?;
        let fwd = get_row_image(&mut r)?;
        let k = r.u32()? as usize;
        let mut applied_upto = Vec::with_capacity(k);
        for _ in 0..k {
            let p = ProcId(r.u32()?);
            applied_upto.push((p, r.u64()?));
        }
        let vis = get_vis(&mut r)?;
        tables.push(TableImage { id, store, fwd, applied_upto, vis });
    }
    Ok(ShardCheckpoint { vclock, last_broadcast, tables })
}

// ---------------------------------------------------------------------------
// File-backed implementation (production).
// ---------------------------------------------------------------------------

/// Directory-backed persistence: `checkpoint.bin` (replaced atomically via
/// tmp + rename), `wal.bin` (framed appends; a torn trailing frame from a
/// mid-write crash is detected and dropped at load), `epoch.bin`.
///
/// Appends go through the OS page cache without `fsync` — the crash model
/// reproduced here is process death, not host death.
pub struct FilePersistence {
    dir: PathBuf,
    wal: Mutex<std::fs::File>,
}

impl FilePersistence {
    /// Open (creating the directory and files as needed).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let wal = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(dir.join("wal.bin"))?;
        Ok(FilePersistence { dir, wal: Mutex::new(wal) })
    }

    fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<()> {
        let tmp = self.dir.join(format!("{name}.tmp"));
        let dst = self.dir.join(name);
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
        }
        std::fs::rename(&tmp, &dst)?;
        Ok(())
    }
}

impl Persistence for FilePersistence {
    fn append(&self, rec: &WalRecord) -> Result<()> {
        let mut body = Vec::new();
        put_wal_record(&mut body, rec);
        let mut frame = Vec::with_capacity(4 + body.len());
        put_u32(&mut frame, body.len() as u32);
        frame.extend_from_slice(&body);
        let mut f = self.wal.lock().unwrap();
        f.write_all(&frame)?;
        Ok(())
    }

    fn checkpoint(&self, cp: &ShardCheckpoint) -> Result<()> {
        // Order matters: the checkpoint lands atomically first, then the WAL
        // is truncated. A crash in between leaves WAL records the checkpoint
        // already covers — replay is idempotent (see module docs).
        self.write_atomic("checkpoint.bin", &encode_checkpoint(cp))?;
        let mut f = self.wal.lock().unwrap();
        *f = std::fs::File::create(self.dir.join("wal.bin"))?;
        Ok(())
    }

    fn load(&self) -> Result<(Option<ShardCheckpoint>, Vec<WalRecord>)> {
        let cp = match std::fs::read(self.dir.join("checkpoint.bin")) {
            Ok(bytes) => Some(decode_checkpoint(&bytes)?),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(e.into()),
        };
        let wal_bytes = match std::fs::read(self.dir.join("wal.bin")) {
            Ok(bytes) => bytes,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        let mut wal = Vec::new();
        let mut pos = 0usize;
        while wal_bytes.len() - pos >= 4 {
            let len = u32::from_le_bytes(wal_bytes[pos..pos + 4].try_into().unwrap()) as usize;
            if wal_bytes.len() - pos - 4 < len {
                break; // torn trailing frame: the append died mid-write
            }
            let mut r = Reader::new(&wal_bytes[pos + 4..pos + 4 + len]);
            wal.push(get_wal_record(&mut r)?);
            pos += 4 + len;
        }
        Ok((cp, wal))
    }

    fn epoch(&self) -> Result<u32> {
        match std::fs::read(self.dir.join("epoch.bin")) {
            Ok(bytes) if bytes.len() == 4 => {
                Ok(u32::from_le_bytes(bytes.as_slice().try_into().unwrap()))
            }
            Ok(_) => Err(corrupt("epoch file")),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(0),
            Err(e) => Err(e.into()),
        }
    }

    fn bump_epoch(&self) -> Result<u32> {
        let next = self.epoch()? + 1;
        self.write_atomic("epoch.bin", &next.to_le_bytes())?;
        Ok(next)
    }
}

// ---------------------------------------------------------------------------
// Checkpoint assembly helpers (shard ⇄ image).
// ---------------------------------------------------------------------------

/// Deterministically image a `TableStore` (rows sorted by id). The values
/// are `Arc` clones of the live rows — O(rows), not O(bytes).
pub fn image_store(store: &TableStore) -> RowImage {
    store.snapshot_rows().into_iter().map(|(id, sr)| (id, sr.data, sr.clock)).collect()
}

/// Deterministically image an applied-frontier map (sorted by origin).
pub fn image_applied(applied: &HashMap<ProcId, u64>) -> Vec<(ProcId, u64)> {
    let mut v: Vec<(ProcId, u64)> = applied.iter().map(|(p, id)| (*p, *id)).collect();
    v.sort_unstable_by_key(|(p, _)| p.0);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::RowKind;

    fn sample_batch(id: u64) -> PushBatch {
        PushBatch {
            table: TableId(0),
            origin: ProcId(1),
            batch_id: id,
            updates: Arc::new(vec![
                (RowId(3), RowUpdate::Dense(vec![1.0, -2.5])),
                (RowId(9), RowUpdate::Sparse(vec![(0, 0.5), (7, -0.25)])),
            ]),
            clock: 4,
            epoch: 2,
            trace: TraceCtx { id: 0xfeed_beef, at_us: 42 },
        }
    }

    fn sample_checkpoint() -> ShardCheckpoint {
        let mut sparse = std::collections::BTreeMap::new();
        sparse.insert(2u32, 1.5f32);
        ShardCheckpoint {
            vclock: vec![(ProcId(0), 3), (ProcId(1), 5)],
            last_broadcast: 3,
            tables: vec![TableImage {
                id: TableId(0),
                store: vec![
                    (RowId(1), Arc::new(RowData::Dense(vec![1.0, 2.0])), 3),
                    (RowId(4), Arc::new(RowData::Sparse(sparse.clone())), 2),
                ],
                fwd: vec![(RowId(1), Arc::new(RowData::Dense(vec![1.0, 0.0])), 3)],
                applied_upto: vec![(ProcId(0), 7), (ProcId(1), 2)],
                vis: VisibilityImage {
                    num_procs: 2,
                    pending: vec![(ProcId(1), 2, vec![ProcId(0)])],
                    inflight: vec![((RowId(1), 0), 1.5)],
                    batch_mass: vec![(ProcId(1), 2, vec![((RowId(1), 0), 1.5)])],
                    held: vec![(ProcId(0), vec![sample_batch(8)])],
                    u_obs: 2.5,
                },
            }],
        }
    }

    fn wal_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Push(sample_batch(0)),
            WalRecord::Ack { table: TableId(0), origin: ProcId(1), batch_id: 0, by: ProcId(0) },
            WalRecord::Clock { proc: ProcId(0), clock: 9 },
        ]
    }

    fn assert_same_checkpoint(a: &ShardCheckpoint, b: &ShardCheckpoint) {
        assert_eq!(encode_checkpoint(a), encode_checkpoint(b));
    }

    #[test]
    fn mem_persistence_roundtrip_and_truncation() {
        let p = MemPersistence::new();
        for rec in wal_records() {
            p.append(&rec).unwrap();
        }
        assert_eq!(p.wal_len(), 3);
        let (cp, wal) = p.load().unwrap();
        assert!(cp.is_none());
        assert_eq!(wal.len(), 3);
        p.checkpoint(&sample_checkpoint()).unwrap();
        assert_eq!(p.wal_len(), 0, "checkpoint truncates the WAL");
        p.append(&WalRecord::Clock { proc: ProcId(1), clock: 1 }).unwrap();
        let (cp, wal) = p.load().unwrap();
        assert_same_checkpoint(&cp.unwrap(), &sample_checkpoint());
        assert_eq!(wal.len(), 1);
        assert_eq!(p.epoch().unwrap(), 0);
        assert_eq!(p.bump_epoch().unwrap(), 1);
        assert_eq!(p.epoch().unwrap(), 1);
    }

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bapps-persist-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn file_persistence_roundtrip_across_reopen() {
        let dir = tempdir("roundtrip");
        {
            let p = FilePersistence::open(&dir).unwrap();
            p.checkpoint(&sample_checkpoint()).unwrap();
            for rec in wal_records() {
                p.append(&rec).unwrap();
            }
            assert_eq!(p.bump_epoch().unwrap(), 1);
            assert_eq!(p.bump_epoch().unwrap(), 2);
        }
        // Reopen: everything must still be there (epoch is durable too).
        let p = FilePersistence::open(&dir).unwrap();
        let (cp, wal) = p.load().unwrap();
        assert_same_checkpoint(&cp.unwrap(), &sample_checkpoint());
        assert_eq!(wal.len(), 3);
        match &wal[0] {
            WalRecord::Push(b) => {
                assert_eq!(b.batch_id, 0);
                assert_eq!(b.updates.len(), 2);
                assert_eq!(b.updates[1].1, RowUpdate::Sparse(vec![(0, 0.5), (7, -0.25)]));
            }
            other => panic!("expected Push, got {other:?}"),
        }
        match &wal[1] {
            WalRecord::Ack { by, .. } => assert_eq!(*by, ProcId(0)),
            other => panic!("expected Ack, got {other:?}"),
        }
        assert_eq!(p.epoch().unwrap(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_persistence_drops_torn_wal_tail() {
        let dir = tempdir("torn");
        {
            let p = FilePersistence::open(&dir).unwrap();
            for rec in wal_records() {
                p.append(&rec).unwrap();
            }
        }
        // Simulate a crash mid-append: a frame header promising more bytes
        // than were written.
        {
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(dir.join("wal.bin"))
                .unwrap();
            f.write_all(&[200, 0, 0, 0, 1, 2, 3]).unwrap();
        }
        let p = FilePersistence::open(&dir).unwrap();
        let (_, wal) = p.load().unwrap();
        assert_eq!(wal.len(), 3, "torn tail ignored, intact prefix kept");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_truncates_wal_file() {
        let dir = tempdir("truncate");
        let p = FilePersistence::open(&dir).unwrap();
        for rec in wal_records() {
            p.append(&rec).unwrap();
        }
        p.checkpoint(&sample_checkpoint()).unwrap();
        p.append(&WalRecord::Clock { proc: ProcId(0), clock: 2 }).unwrap();
        let (cp, wal) = p.load().unwrap();
        assert!(cp.is_some());
        assert_eq!(wal.len(), 1, "only post-checkpoint records remain");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_checkpoint_is_an_error_not_a_panic() {
        let dir = tempdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("checkpoint.bin"), b"not a checkpoint").unwrap();
        let p = FilePersistence::open(&dir).unwrap();
        assert!(p.load().is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
