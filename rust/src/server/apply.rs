//! Parallel push-apply worker pool.
//!
//! The shard event loop stays the single sequencer — it receives batches in
//! bus order and hands each one to the pool, which fans the row updates
//! across worker lanes and **barriers** before the loop touches the next
//! message. Lane assignment is `stripe_of(row) % num_lanes`: every row maps
//! to exactly one lane for the lifetime of the pool, so the updates touching
//! a given row are always applied by the same worker, in slice order. That
//! preserves the per-row apply order of the sequential path exactly — float
//! addition is order-sensitive, and the deterministic simulator's per-seed
//! byte-identity depends on it.
//!
//! Workers never contend on a stripe: distinct lanes own disjoint stripe
//! sets, so the striped [`TableStore`] locks are uncontended during a
//! fan-out (pulls may still share stripes read-side, which `RwLock` allows).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::table::{RowId, RowUpdate, TableStore};

/// One fan-out unit: apply `updates` (the lane's subset) against `store`.
struct Job {
    store: Arc<TableStore>,
    updates: Arc<Vec<(RowId, RowUpdate)>>,
    done: Sender<()>,
}

/// Fixed pool of apply workers, one lane each.
///
/// `apply` dispatches a batch to every lane and blocks until all lanes
/// report done — a per-batch barrier, so from the event loop's perspective
/// the call is indistinguishable from a sequential apply (just faster on
/// multi-core hosts).
pub struct ApplyPool {
    lanes: Vec<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    batches: AtomicU64,
}

impl std::fmt::Debug for ApplyPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ApplyPool").field("lanes", &self.lanes.len()).finish()
    }
}

impl ApplyPool {
    /// Spawn `threads` workers for shard `shard` (thread names
    /// `apply{shard}-{lane}`). `threads` is clamped to ≥ 1; a 1-lane pool
    /// is functional but pointless — callers keep the inline path for that.
    pub fn new(shard: u32, threads: u32) -> Self {
        let threads = threads.max(1) as usize;
        let mut lanes = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for lane in 0..threads {
            let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
            // Receiver into a worker thread; Mutex only to satisfy the
            // builder closure's move semantics cleanly.
            let rx = Mutex::new(rx);
            let handle = std::thread::Builder::new()
                .name(format!("apply{shard}-{lane}"))
                .spawn(move || {
                    let rx = rx.lock().expect("apply lane rx");
                    while let Ok(job) = rx.recv() {
                        job.store.apply_lane(&job.updates, lane, threads);
                        // Receiver may be gone if the dispatcher panicked
                        // mid-barrier; nothing to do but drop the signal.
                        let _ = job.done.send(());
                    }
                })
                .expect("spawn apply worker");
            lanes.push(tx);
            workers.push(handle);
        }
        ApplyPool { lanes, workers, batches: AtomicU64::new(0) }
    }

    /// Number of lanes (worker threads).
    pub fn num_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Batches fanned out so far (drained by the shard's metrics hook).
    pub fn batches_fanned(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Fan one batch's updates across all lanes and wait for every lane to
    /// finish (barrier). Row → lane assignment is stable, so per-row apply
    /// order equals the sequential slice order.
    pub fn apply(&self, store: &Arc<TableStore>, updates: &Arc<Vec<(RowId, RowUpdate)>>) {
        let (done_tx, done_rx) = channel();
        for lane in &self.lanes {
            let job = Job {
                store: Arc::clone(store),
                updates: Arc::clone(updates),
                done: done_tx.clone(),
            };
            lane.send(job).expect("apply lane died");
        }
        drop(done_tx);
        for _ in 0..self.lanes.len() {
            done_rx.recv().expect("apply lane died mid-batch");
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
    }
}

impl Drop for ApplyPool {
    fn drop(&mut self) {
        // Closing the senders ends each worker's recv loop.
        self.lanes.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::RowKind;

    fn seq_store(updates: &[(RowId, RowUpdate)]) -> TableStore {
        let store = TableStore::new(RowKind::Dense, 4);
        for (row, u) in updates {
            store.apply(*row, u);
        }
        store
    }

    fn make_updates(n: u64, rows: u64) -> Vec<(RowId, RowUpdate)> {
        (0..n)
            .map(|i| (RowId(i % rows), RowUpdate::single((i % 4) as u32, 0.1 + i as f32 * 0.01)))
            .collect()
    }

    #[test]
    fn pooled_apply_matches_sequential() {
        let updates = Arc::new(make_updates(500, 23));
        let expect = seq_store(&updates);
        for threads in [1u32, 2, 3, 4] {
            let pool = ApplyPool::new(0, threads);
            let store = Arc::new(TableStore::new(RowKind::Dense, 4));
            pool.apply(&store, &updates);
            for (row, sr) in expect.snapshot_rows() {
                let got = store.get(row).expect("row present");
                assert_eq!(*got.data, *sr.data, "threads={threads} row={row:?}");
            }
            assert_eq!(store.len(), expect.len());
        }
    }

    #[test]
    fn barrier_completes_before_return() {
        let pool = ApplyPool::new(1, 4);
        let store = Arc::new(TableStore::new(RowKind::Dense, 4));
        for _ in 0..50 {
            let updates = Arc::new(make_updates(64, 64));
            pool.apply(&store, &updates);
        }
        // Every apply barriered, so all 50 * 64 updates are visible now.
        assert_eq!(pool.batches_fanned(), 50);
        assert_eq!(store.len(), 64);
    }
}
