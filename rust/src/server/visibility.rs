//! Batch-visibility bookkeeping and the strong-VAP release gate.
//!
//! A pushed batch goes through three states at the owning shard:
//!
//! 1. **applied** — merged into the shard's authoritative rows;
//! 2. **in flight** ("half-synchronized" once ≥ 1 foreign process applied
//!    it) — forwarded to the `P` client processes, awaiting their acks;
//! 3. **globally visible** — all `P` acks received; the shard notifies the
//!    origin, whose VAP accounting releases the batch's mass.
//!
//! Under **strong VAP** (paper §2.2) the transition 1→2 is gated: the
//! total in-flight L1 mass per parameter may not exceed
//! `max(u_obs, v_thr)`. Held batches queue **per origin** so FIFO update
//! visibility per worker is preserved (releasing origin B's batch while
//! origin A's waits is allowed — FIFO is per sender).

use std::collections::{BTreeSet, HashMap, VecDeque};

use crate::comm::msg::PushBatch;
use crate::consistency::ConsistencyModel;
use crate::table::RowId;
use crate::types::ProcId;

/// Per-parameter key used for in-flight mass accounting.
pub type ParamKey = (RowId, u32);

/// Tracks ack sets, in-flight mass and held batches for one table on one
/// shard.
pub struct VisibilityTracker {
    /// Expected acks per batch = number of client processes.
    num_procs: u32,
    /// `(origin, batch_id) → processes that have acked`. Set-based rather
    /// than a countdown so that a duplicate ack — normal after a recovered
    /// shard re-solicits acks with `AckProbe` — cannot double-count.
    pending: HashMap<(ProcId, u64), BTreeSet<ProcId>>,
    /// Strong-VAP: in-flight L1 mass per parameter.
    inflight: HashMap<ParamKey, f32>,
    /// Strong-VAP: the per-parameter masses each in-flight batch carries
    /// (so they can be released on final ack).
    batch_mass: HashMap<(ProcId, u64), Vec<(ParamKey, f32)>>,
    /// Strong-VAP: batches held back by the release gate, FIFO per origin.
    held: HashMap<ProcId, VecDeque<PushBatch>>,
    /// Largest single-update magnitude observed (the paper's `u`).
    u_obs: f32,
}

impl VisibilityTracker {
    /// New tracker expecting `num_procs` acks per batch.
    pub fn new(num_procs: u32) -> Self {
        VisibilityTracker {
            num_procs,
            pending: HashMap::new(),
            inflight: HashMap::new(),
            batch_mass: HashMap::new(),
            held: HashMap::new(),
            u_obs: 0.0,
        }
    }

    /// Observed per-update magnitude bound `u` so far.
    pub fn u_obs(&self) -> f32 {
        self.u_obs
    }

    /// Record the magnitudes contained in a freshly applied batch (keeps
    /// `u_obs` current regardless of gating).
    pub fn observe(&mut self, batch: &PushBatch) {
        for (_, u) in batch.updates.iter() {
            self.u_obs = self.u_obs.max(u.magnitude());
        }
    }

    /// Try to admit `batch` for forwarding under `model`'s release gate.
    /// Returns `Some(batch)` if it may be forwarded now (in-flight
    /// accounting already updated), or `None` if it was queued. Batches
    /// from an origin with queued predecessors are always queued to keep
    /// per-origin FIFO.
    pub fn admit(&mut self, model: &ConsistencyModel, batch: PushBatch) -> Option<PushBatch> {
        let origin_queue_nonempty =
            self.held.get(&batch.origin).map_or(false, |q| !q.is_empty());
        if origin_queue_nonempty || !self.gate_passes(model, &batch) {
            self.held.entry(batch.origin).or_default().push_back(batch);
            return None;
        }
        self.start_flight(model, &batch);
        Some(batch)
    }

    /// Record `by`'s ack of `(origin, batch_id)`. Returns `true` when that
    /// completed the ack set (batch now globally visible). Duplicate acks
    /// from the same process and acks for unknown (already-visible) batches
    /// are ignored.
    pub fn ack(&mut self, origin: ProcId, batch_id: u64, by: ProcId) -> bool {
        match self.pending.get_mut(&(origin, batch_id)) {
            Some(acked) => {
                if !acked.insert(by) {
                    return false; // duplicate ack (e.g. re-ack after AckProbe)
                }
                if acked.len() as u32 == self.num_procs {
                    self.pending.remove(&(origin, batch_id));
                    if let Some(masses) = self.batch_mass.remove(&(origin, batch_id)) {
                        for (param, m) in masses {
                            if let Some(v) = self.inflight.get_mut(&param) {
                                *v -= m;
                                if *v <= 0.0 {
                                    self.inflight.remove(&param);
                                }
                            }
                        }
                    }
                    true
                } else {
                    false
                }
            }
            None => false, // unknown/already-visible batch: ignore
        }
    }

    /// In-flight batches with the processes that have **not** acked yet —
    /// the targets of a recovered shard's `AckProbe`s (the original acks may
    /// have been lost in the crash window). Sorted `(origin, batch_id)` so
    /// probe emission order is deterministic.
    pub fn missing_acks(&self) -> Vec<(ProcId, u64, Vec<ProcId>)> {
        let mut out: Vec<(ProcId, u64, Vec<ProcId>)> = self
            .pending
            .iter()
            .map(|((o, b), acked)| {
                let missing: Vec<ProcId> =
                    (0..self.num_procs).map(ProcId).filter(|p| !acked.contains(p)).collect();
                (*o, *b, missing)
            })
            .collect();
        out.sort_unstable_by_key(|(o, b, _)| (o.0, *b));
        out
    }

    /// After a release of in-flight mass, pop every held batch that now
    /// passes the gate (per-origin FIFO, round-robin across origins).
    pub fn release_ready(&mut self, model: &ConsistencyModel) -> Vec<PushBatch> {
        let mut out = Vec::new();
        loop {
            let mut progressed = false;
            // Origin order: the release sequence (and therefore the forward
            // message order) must be a pure function of tracker state for
            // the deterministic simulator's trace-identity guarantee.
            let mut origins: Vec<ProcId> = self
                .held
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .map(|(o, _)| *o)
                .collect();
            origins.sort_unstable_by_key(|o| o.0);
            for origin in origins {
                let passes = {
                    let q = self.held.get(&origin).unwrap();
                    q.front().map_or(false, |b| self.gate_passes(model, b))
                };
                if passes {
                    let batch = self.held.get_mut(&origin).unwrap().pop_front().unwrap();
                    self.start_flight(model, &batch);
                    out.push(batch);
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        out
    }

    /// Number of batches currently held by the gate (all origins).
    pub fn held_count(&self) -> usize {
        self.held.values().map(|q| q.len()).sum()
    }

    /// The smallest clock stamp over all held batches, if any. The shard
    /// clamps its broadcast min clock below this: a `MinClock(m)`
    /// broadcast asserts every update stamped `≤ m` has been *forwarded*,
    /// which held batches would violate (matters for strong CVAP, where
    /// the clock gate and the release gate coexist).
    pub fn min_held_clock(&self) -> Option<crate::types::Clock> {
        self.held.values().flat_map(|q| q.iter().map(|b| b.clock)).min()
    }

    /// Number of batches awaiting acks.
    pub fn in_flight_count(&self) -> usize {
        self.pending.len()
    }

    /// Current in-flight mass of one parameter (tests/benches).
    pub fn inflight_mass(&self, param: ParamKey) -> f32 {
        self.inflight.get(&param).copied().unwrap_or(0.0)
    }

    fn gate_passes(&self, model: &ConsistencyModel, batch: &PushBatch) -> bool {
        if !model.release_gated() {
            // The gate is a constant `false` for this model; skip the
            // per-parameter walk entirely.
            return true;
        }
        for (row, u) in batch.updates.iter() {
            for (col, v) in u.iter_nonzero() {
                let key = (*row, col);
                let inflight = self.inflight.get(&key).copied().unwrap_or(0.0);
                if model.release_blocked(inflight, v.abs(), self.u_obs) {
                    return false;
                }
            }
        }
        true
    }

    fn start_flight(&mut self, model: &ConsistencyModel, batch: &PushBatch) {
        self.pending.insert((batch.origin, batch.batch_id), BTreeSet::new());
        // Per-parameter mass is only consumed by the strong-VAP/CVAP release
        // gate; for every other model it would be dead weight accumulated on
        // the push hot path (and `ack` already tolerates its absence).
        if !model.release_gated() {
            return;
        }
        let mut masses = Vec::new();
        for (row, u) in batch.updates.iter() {
            for (col, v) in u.iter_nonzero() {
                let key = (*row, col);
                *self.inflight.entry(key).or_insert(0.0) += v.abs();
                masses.push((key, v.abs()));
            }
        }
        self.batch_mass.insert((batch.origin, batch.batch_id), masses);
    }

    /// Plain-data image of the tracker (sorted, deterministic) for shard
    /// checkpointing.
    pub fn export(&self) -> VisibilityImage {
        let mut pending: Vec<(ProcId, u64, Vec<ProcId>)> = self
            .pending
            .iter()
            .map(|((o, b), acked)| (*o, *b, acked.iter().copied().collect()))
            .collect();
        pending.sort_unstable_by_key(|(o, b, _)| (o.0, *b));
        let mut inflight: Vec<(ParamKey, f32)> =
            self.inflight.iter().map(|(k, v)| (*k, *v)).collect();
        inflight.sort_unstable_by_key(|((r, c), _)| (r.0, *c));
        let mut batch_mass: Vec<(ProcId, u64, Vec<(ParamKey, f32)>)> =
            self.batch_mass.iter().map(|((o, b), m)| (*o, *b, m.clone())).collect();
        batch_mass.sort_unstable_by_key(|(o, b, _)| (o.0, *b));
        let mut held: Vec<(ProcId, Vec<PushBatch>)> = self
            .held
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(o, q)| (*o, q.iter().cloned().collect()))
            .collect();
        held.sort_unstable_by_key(|(o, _)| o.0);
        VisibilityImage {
            num_procs: self.num_procs,
            pending,
            inflight,
            batch_mass,
            held,
            u_obs: self.u_obs,
        }
    }

    /// Rebuild a tracker from a checkpoint image.
    pub fn from_image(img: VisibilityImage) -> Self {
        VisibilityTracker {
            num_procs: img.num_procs,
            pending: img
                .pending
                .into_iter()
                .map(|(o, b, acked)| ((o, b), acked.into_iter().collect()))
                .collect(),
            inflight: img.inflight.into_iter().collect(),
            batch_mass: img.batch_mass.into_iter().map(|(o, b, m)| ((o, b), m)).collect(),
            held: img.held.into_iter().map(|(o, q)| (o, q.into_iter().collect())).collect(),
            u_obs: img.u_obs,
        }
    }
}

/// Plain-data, deterministically ordered snapshot of a
/// [`VisibilityTracker`], serialisable by the persistence layer.
#[derive(Debug, Clone)]
pub struct VisibilityImage {
    /// Expected acks per batch.
    pub num_procs: u32,
    /// In-flight batches and the processes that have acked each.
    pub pending: Vec<(ProcId, u64, Vec<ProcId>)>,
    /// Strong-VAP per-parameter in-flight mass.
    pub inflight: Vec<(ParamKey, f32)>,
    /// Per-batch masses (released on final ack).
    pub batch_mass: Vec<(ProcId, u64, Vec<(ParamKey, f32)>)>,
    /// Gate-held batches, FIFO per origin.
    pub held: Vec<(ProcId, Vec<PushBatch>)>,
    /// Observed per-update magnitude bound `u`.
    pub u_obs: f32,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::table::{RowUpdate, TableId};

    fn batch(origin: u32, id: u64, row: u64, delta: f32) -> PushBatch {
        PushBatch {
            table: TableId(0),
            origin: ProcId(origin),
            batch_id: id,
            updates: std::sync::Arc::new(vec![(RowId(row), RowUpdate::single(0, delta))]),
            clock: 0,
            epoch: 0,
            trace: crate::trace::TraceCtx::NONE,
        }
    }

    fn weak() -> ConsistencyModel {
        ConsistencyModel::new(PolicyConfig::Vap { v_thr: 4.0, strong: false })
    }
    fn strong() -> ConsistencyModel {
        ConsistencyModel::new(PolicyConfig::Vap { v_thr: 4.0, strong: true })
    }

    #[test]
    fn weak_vap_admits_everything() {
        let mut t = VisibilityTracker::new(2);
        let m = weak();
        for i in 0..20 {
            let b = batch(0, i, 0, 3.0);
            t.observe(&b);
            assert!(t.admit(&m, b).is_some());
        }
        assert_eq!(t.held_count(), 0);
        assert_eq!(t.in_flight_count(), 20);
    }

    #[test]
    fn final_ack_marks_visible() {
        let mut t = VisibilityTracker::new(3);
        let m = weak();
        let b = batch(1, 7, 0, 1.0);
        t.observe(&b);
        t.admit(&m, b).unwrap();
        assert!(!t.ack(ProcId(1), 7, ProcId(0)));
        assert!(!t.ack(ProcId(1), 7, ProcId(1)));
        assert!(t.ack(ProcId(1), 7, ProcId(2)), "third distinct ack is final");
        assert!(!t.ack(ProcId(1), 7, ProcId(2)), "ack after visibility ignored");
        assert_eq!(t.in_flight_count(), 0);
    }

    #[test]
    fn duplicate_acks_from_same_proc_do_not_count() {
        let mut t = VisibilityTracker::new(3);
        let m = weak();
        let b = batch(0, 0, 0, 1.0);
        t.observe(&b);
        t.admit(&m, b).unwrap();
        // The same process re-acking (as after an AckProbe) must not bring
        // the batch closer to visibility.
        assert!(!t.ack(ProcId(0), 0, ProcId(2)));
        assert!(!t.ack(ProcId(0), 0, ProcId(2)));
        assert!(!t.ack(ProcId(0), 0, ProcId(2)));
        assert_eq!(t.in_flight_count(), 1);
        assert!(!t.ack(ProcId(0), 0, ProcId(0)));
        assert!(t.ack(ProcId(0), 0, ProcId(1)));
    }

    #[test]
    fn missing_acks_lists_unacked_procs_in_order() {
        let mut t = VisibilityTracker::new(3);
        let m = weak();
        for id in 0..2u64 {
            let b = batch(1, id, 0, 1.0);
            t.observe(&b);
            t.admit(&m, b).unwrap();
        }
        t.ack(ProcId(1), 1, ProcId(2));
        let missing = t.missing_acks();
        assert_eq!(missing.len(), 2);
        assert_eq!(missing[0], (ProcId(1), 0, vec![ProcId(0), ProcId(1), ProcId(2)]));
        assert_eq!(missing[1], (ProcId(1), 1, vec![ProcId(0), ProcId(1)]));
    }

    #[test]
    fn export_import_roundtrip_preserves_tracker_state() {
        let mut t = VisibilityTracker::new(2);
        let m = strong();
        for id in 0..3u64 {
            let b = batch(0, id, 5, 3.0);
            t.observe(&b);
            t.admit(&m, b); // id 0 admitted; 1, 2 held by the gate
        }
        t.ack(ProcId(0), 0, ProcId(1));
        let mut r = VisibilityTracker::from_image(t.export());
        assert_eq!(r.u_obs(), t.u_obs());
        assert_eq!(r.held_count(), t.held_count());
        assert_eq!(r.in_flight_count(), t.in_flight_count());
        assert_eq!(r.inflight_mass((RowId(5), 0)), t.inflight_mass((RowId(5), 0)));
        assert_eq!(r.missing_acks(), t.missing_acks());
        // The restored tracker continues exactly where the original was:
        // the second (final) ack for batch 0 releases batch 1 from the gate.
        assert!(r.ack(ProcId(0), 0, ProcId(0)));
        let rel = r.release_ready(&m);
        assert_eq!(rel.len(), 1);
        assert_eq!(rel[0].batch_id, 1);
    }

    #[test]
    fn strong_gate_holds_second_batch_on_same_param() {
        let mut t = VisibilityTracker::new(2);
        let m = strong();
        // v_thr = 4: first batch of mass 3 admitted; second of mass 3 on the
        // same param would make in-flight 6 > 4 → held.
        let b1 = batch(0, 0, 5, 3.0);
        t.observe(&b1);
        assert!(t.admit(&m, b1).is_some());
        let b2 = batch(0, 1, 5, 3.0);
        t.observe(&b2);
        assert!(t.admit(&m, b2).is_none());
        assert_eq!(t.held_count(), 1);
        assert_eq!(t.inflight_mass((RowId(5), 0)), 3.0);

        // Acks for b1 release mass; b2 becomes forwardable.
        t.ack(ProcId(0), 0, ProcId(0));
        assert!(t.ack(ProcId(0), 0, ProcId(1)));
        let released = t.release_ready(&m);
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].batch_id, 1);
        assert_eq!(t.inflight_mass((RowId(5), 0)), 3.0);
    }

    #[test]
    fn strong_gate_preserves_per_origin_fifo() {
        let mut t = VisibilityTracker::new(1);
        let m = strong();
        let b1 = batch(0, 0, 5, 3.0);
        t.observe(&b1);
        t.admit(&m, b1).unwrap();
        // batch 1 held (same param), batch 2 touches another row but must
        // queue behind batch 1 (same origin).
        let b2 = batch(0, 1, 5, 3.0);
        t.observe(&b2);
        assert!(t.admit(&m, b2).is_none());
        let b3 = batch(0, 2, 99, 0.5);
        t.observe(&b3);
        assert!(t.admit(&m, b3).is_none(), "must queue behind held predecessor");
        // another origin is NOT blocked
        let b4 = batch(1, 0, 99, 0.5);
        t.observe(&b4);
        assert!(t.admit(&m, b4).is_some());

        t.ack(ProcId(0), 0, ProcId(0));
        let rel = t.release_ready(&m);
        let ids: Vec<u64> = rel.iter().map(|b| b.batch_id).collect();
        assert_eq!(ids, vec![1, 2], "held batches release in origin order");
    }

    #[test]
    fn oversized_batch_admitted_when_param_idle() {
        let mut t = VisibilityTracker::new(1);
        let m = strong();
        let b = batch(0, 0, 1, 100.0); // way over v_thr
        t.observe(&b);
        assert_eq!(t.u_obs(), 100.0);
        assert!(t.admit(&m, b).is_some(), "idle param admits oversized batch");
    }
}
