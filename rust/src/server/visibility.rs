//! Batch-visibility bookkeeping and the strong-VAP release gate.
//!
//! A pushed batch goes through three states at the owning shard:
//!
//! 1. **applied** — merged into the shard's authoritative rows;
//! 2. **in flight** ("half-synchronized" once ≥ 1 foreign process applied
//!    it) — forwarded to the `P` client processes, awaiting their acks;
//! 3. **globally visible** — all `P` acks received; the shard notifies the
//!    origin, whose VAP accounting releases the batch's mass.
//!
//! Under **strong VAP** (paper §2.2) the transition 1→2 is gated: the
//! total in-flight L1 mass per parameter may not exceed
//! `max(u_obs, v_thr)`. Held batches queue **per origin** so FIFO update
//! visibility per worker is preserved (releasing origin B's batch while
//! origin A's waits is allowed — FIFO is per sender).

use std::collections::{HashMap, VecDeque};

use crate::comm::msg::PushBatch;
use crate::consistency::ConsistencyModel;
use crate::table::RowId;
use crate::types::ProcId;

/// Per-parameter key used for in-flight mass accounting.
pub type ParamKey = (RowId, u32);

/// Tracks ack counts, in-flight mass and held batches for one table on one
/// shard.
pub struct VisibilityTracker {
    /// Expected acks per batch = number of client processes.
    num_procs: u32,
    /// `(origin, batch_id) → acks still missing`.
    pending: HashMap<(ProcId, u64), u32>,
    /// Strong-VAP: in-flight L1 mass per parameter.
    inflight: HashMap<ParamKey, f32>,
    /// Strong-VAP: the per-parameter masses each in-flight batch carries
    /// (so they can be released on final ack).
    batch_mass: HashMap<(ProcId, u64), Vec<(ParamKey, f32)>>,
    /// Strong-VAP: batches held back by the release gate, FIFO per origin.
    held: HashMap<ProcId, VecDeque<PushBatch>>,
    /// Largest single-update magnitude observed (the paper's `u`).
    u_obs: f32,
}

impl VisibilityTracker {
    /// New tracker expecting `num_procs` acks per batch.
    pub fn new(num_procs: u32) -> Self {
        VisibilityTracker {
            num_procs,
            pending: HashMap::new(),
            inflight: HashMap::new(),
            batch_mass: HashMap::new(),
            held: HashMap::new(),
            u_obs: 0.0,
        }
    }

    /// Observed per-update magnitude bound `u` so far.
    pub fn u_obs(&self) -> f32 {
        self.u_obs
    }

    /// Record the magnitudes contained in a freshly applied batch (keeps
    /// `u_obs` current regardless of gating).
    pub fn observe(&mut self, batch: &PushBatch) {
        for (_, u) in &batch.updates {
            self.u_obs = self.u_obs.max(u.magnitude());
        }
    }

    /// Try to admit `batch` for forwarding under `model`'s release gate.
    /// Returns `Some(batch)` if it may be forwarded now (in-flight
    /// accounting already updated), or `None` if it was queued. Batches
    /// from an origin with queued predecessors are always queued to keep
    /// per-origin FIFO.
    pub fn admit(&mut self, model: &ConsistencyModel, batch: PushBatch) -> Option<PushBatch> {
        let origin_queue_nonempty =
            self.held.get(&batch.origin).map_or(false, |q| !q.is_empty());
        if origin_queue_nonempty || !self.gate_passes(model, &batch) {
            self.held.entry(batch.origin).or_default().push_back(batch);
            return None;
        }
        self.start_flight(&batch);
        Some(batch)
    }

    /// Record one process's ack of `(origin, batch_id)`. Returns `true`
    /// when that was the final ack (batch now globally visible).
    pub fn ack(&mut self, origin: ProcId, batch_id: u64) -> bool {
        match self.pending.get_mut(&(origin, batch_id)) {
            Some(n) => {
                *n -= 1;
                if *n == 0 {
                    self.pending.remove(&(origin, batch_id));
                    if let Some(masses) = self.batch_mass.remove(&(origin, batch_id)) {
                        for (param, m) in masses {
                            if let Some(v) = self.inflight.get_mut(&param) {
                                *v -= m;
                                if *v <= 0.0 {
                                    self.inflight.remove(&param);
                                }
                            }
                        }
                    }
                    true
                } else {
                    false
                }
            }
            None => false, // duplicate/unknown ack: ignore
        }
    }

    /// After a release of in-flight mass, pop every held batch that now
    /// passes the gate (per-origin FIFO, round-robin across origins).
    pub fn release_ready(&mut self, model: &ConsistencyModel) -> Vec<PushBatch> {
        let mut out = Vec::new();
        loop {
            let mut progressed = false;
            // Origin order: the release sequence (and therefore the forward
            // message order) must be a pure function of tracker state for
            // the deterministic simulator's trace-identity guarantee.
            let mut origins: Vec<ProcId> = self
                .held
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .map(|(o, _)| *o)
                .collect();
            origins.sort_unstable_by_key(|o| o.0);
            for origin in origins {
                let passes = {
                    let q = self.held.get(&origin).unwrap();
                    q.front().map_or(false, |b| self.gate_passes(model, b))
                };
                if passes {
                    let batch = self.held.get_mut(&origin).unwrap().pop_front().unwrap();
                    self.start_flight(&batch);
                    out.push(batch);
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        out
    }

    /// Number of batches currently held by the gate (all origins).
    pub fn held_count(&self) -> usize {
        self.held.values().map(|q| q.len()).sum()
    }

    /// The smallest clock stamp over all held batches, if any. The shard
    /// clamps its broadcast min clock below this: a `MinClock(m)`
    /// broadcast asserts every update stamped `≤ m` has been *forwarded*,
    /// which held batches would violate (matters for strong CVAP, where
    /// the clock gate and the release gate coexist).
    pub fn min_held_clock(&self) -> Option<crate::types::Clock> {
        self.held.values().flat_map(|q| q.iter().map(|b| b.clock)).min()
    }

    /// Number of batches awaiting acks.
    pub fn in_flight_count(&self) -> usize {
        self.pending.len()
    }

    /// Current in-flight mass of one parameter (tests/benches).
    pub fn inflight_mass(&self, param: ParamKey) -> f32 {
        self.inflight.get(&param).copied().unwrap_or(0.0)
    }

    fn gate_passes(&self, model: &ConsistencyModel, batch: &PushBatch) -> bool {
        for (row, u) in &batch.updates {
            for (col, v) in u.iter_nonzero() {
                let key = (*row, col);
                let inflight = self.inflight.get(&key).copied().unwrap_or(0.0);
                if model.release_blocked(inflight, v.abs(), self.u_obs) {
                    return false;
                }
            }
        }
        true
    }

    fn start_flight(&mut self, batch: &PushBatch) {
        self.pending.insert((batch.origin, batch.batch_id), self.num_procs);
        let mut masses = Vec::new();
        for (row, u) in &batch.updates {
            for (col, v) in u.iter_nonzero() {
                let key = (*row, col);
                *self.inflight.entry(key).or_insert(0.0) += v.abs();
                masses.push((key, v.abs()));
            }
        }
        self.batch_mass.insert((batch.origin, batch.batch_id), masses);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::table::{RowUpdate, TableId};

    fn batch(origin: u32, id: u64, row: u64, delta: f32) -> PushBatch {
        PushBatch {
            table: TableId(0),
            origin: ProcId(origin),
            batch_id: id,
            updates: vec![(RowId(row), RowUpdate::single(0, delta))],
            clock: 0,
        }
    }

    fn weak() -> ConsistencyModel {
        ConsistencyModel::new(PolicyConfig::Vap { v_thr: 4.0, strong: false })
    }
    fn strong() -> ConsistencyModel {
        ConsistencyModel::new(PolicyConfig::Vap { v_thr: 4.0, strong: true })
    }

    #[test]
    fn weak_vap_admits_everything() {
        let mut t = VisibilityTracker::new(2);
        let m = weak();
        for i in 0..20 {
            let b = batch(0, i, 0, 3.0);
            t.observe(&b);
            assert!(t.admit(&m, b).is_some());
        }
        assert_eq!(t.held_count(), 0);
        assert_eq!(t.in_flight_count(), 20);
    }

    #[test]
    fn final_ack_marks_visible() {
        let mut t = VisibilityTracker::new(3);
        let m = weak();
        let b = batch(1, 7, 0, 1.0);
        t.observe(&b);
        t.admit(&m, b).unwrap();
        assert!(!t.ack(ProcId(1), 7));
        assert!(!t.ack(ProcId(1), 7));
        assert!(t.ack(ProcId(1), 7), "third ack is final");
        assert!(!t.ack(ProcId(1), 7), "duplicate ack ignored");
        assert_eq!(t.in_flight_count(), 0);
    }

    #[test]
    fn strong_gate_holds_second_batch_on_same_param() {
        let mut t = VisibilityTracker::new(2);
        let m = strong();
        // v_thr = 4: first batch of mass 3 admitted; second of mass 3 on the
        // same param would make in-flight 6 > 4 → held.
        let b1 = batch(0, 0, 5, 3.0);
        t.observe(&b1);
        assert!(t.admit(&m, b1).is_some());
        let b2 = batch(0, 1, 5, 3.0);
        t.observe(&b2);
        assert!(t.admit(&m, b2).is_none());
        assert_eq!(t.held_count(), 1);
        assert_eq!(t.inflight_mass((RowId(5), 0)), 3.0);

        // Acks for b1 release mass; b2 becomes forwardable.
        t.ack(ProcId(0), 0);
        assert!(t.ack(ProcId(0), 0));
        let released = t.release_ready(&m);
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].batch_id, 1);
        assert_eq!(t.inflight_mass((RowId(5), 0)), 3.0);
    }

    #[test]
    fn strong_gate_preserves_per_origin_fifo() {
        let mut t = VisibilityTracker::new(1);
        let m = strong();
        let b1 = batch(0, 0, 5, 3.0);
        t.observe(&b1);
        t.admit(&m, b1).unwrap();
        // batch 1 held (same param), batch 2 touches another row but must
        // queue behind batch 1 (same origin).
        let b2 = batch(0, 1, 5, 3.0);
        t.observe(&b2);
        assert!(t.admit(&m, b2).is_none());
        let b3 = batch(0, 2, 99, 0.5);
        t.observe(&b3);
        assert!(t.admit(&m, b3).is_none(), "must queue behind held predecessor");
        // another origin is NOT blocked
        let b4 = batch(1, 0, 99, 0.5);
        t.observe(&b4);
        assert!(t.admit(&m, b4).is_some());

        t.ack(ProcId(0), 0);
        let rel = t.release_ready(&m);
        let ids: Vec<u64> = rel.iter().map(|b| b.batch_id).collect();
        assert_eq!(ids, vec![1, 2], "held batches release in origin order");
    }

    #[test]
    fn oversized_batch_admitted_when_param_idle() {
        let mut t = VisibilityTracker::new(1);
        let m = strong();
        let b = batch(0, 0, 1, 100.0); // way over v_thr
        t.observe(&b);
        assert_eq!(t.u_obs(), 100.0);
        assert!(t.admit(&m, b).is_some(), "idle param admits oversized batch");
    }
}
