//! The in-process network: endpoints, delayed FIFO delivery, bandwidth.
//!
//! Design:
//! * every node registers an [`Endpoint`] (an mpsc receiver);
//! * senders go through a shared [`NetSender`];
//! * with an **ideal** network profile (zero latency/bandwidth) messages are
//!   forwarded directly to the destination channel — the fast path used by
//!   most tests and by throughput-oriented benches;
//! * with a **simulated** profile, messages are injected into a single
//!   dispatcher thread that holds a min-heap of `(deliver_at, seq, msg)` and
//!   releases each message at its due time. Per-link FIFO is enforced by
//!   never scheduling a message earlier than the link's previous one, even
//!   under jitter — FIFO consistency (paper §2) depends on it.
//!
//! Bandwidth is modeled per directed link: a message of `b` bytes occupies
//! the link for `b / bandwidth` seconds, so a backlog of large update
//! batches delays everything behind it (the congestion regime that makes
//! best-effort systems diverge, paper §1).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::NetConfig;
use crate::error::{Error, Result};
use crate::metrics::NetMetrics;
use crate::types::NodeId;
use crate::util::Rng64;

use super::msg::Msg;

/// Receiving side of a node's mailbox.
pub struct Endpoint {
    /// This endpoint's address.
    pub node: NodeId,
    rx: Receiver<Msg>,
}

impl Endpoint {
    /// Block until the next message arrives.
    pub fn recv(&self) -> Result<Msg> {
        self.rx.recv().map_err(|_| Error::Disconnected(self.node))
    }

    /// Block with a timeout; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, d: Duration) -> Result<Option<Msg>> {
        match self.rx.recv_timeout(d) {
            Ok(m) => Ok(Some(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Disconnected(self.node)),
        }
    }

    /// Non-blocking poll.
    pub fn try_recv(&self) -> Option<Msg> {
        self.rx.try_recv().ok()
    }
}

/// Per-directed-link state for FIFO + bandwidth accounting.
#[derive(Default)]
struct LinkState {
    /// The link is serialized: busy until this instant.
    busy_until: Option<Instant>,
    /// Monotone delivery floor (FIFO even under jitter).
    last_delivery: Option<Instant>,
}

/// Heap entry ordered by delivery time then injection sequence.
struct Scheduled {
    at: Instant,
    seq: u64,
    msg: Msg,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

struct Shared {
    mailboxes: Mutex<HashMap<NodeId, Sender<Msg>>>,
    links: Mutex<HashMap<(NodeId, NodeId), LinkState>>,
    /// Jitter RNG (under the links lock).
    jitter_rng: Mutex<Rng64>,
    net: NetConfig,
    metrics: Arc<NetMetrics>,
    seq: AtomicU64,
    /// Whether this network delays messages (dispatcher active).
    delayed: bool,
    /// Injection channel into the dispatcher (None on the ideal fast path
    /// or after shutdown). Behind a mutex so `Network::drop` can sever it —
    /// the dispatcher exits when every sender is gone.
    inject: Mutex<Option<Sender<Scheduled>>>,
}

/// Pluggable delivery backend behind [`NetSender`].
///
/// The production implementation is the in-process bus below ([`Network`]
/// hands out senders wired to it); the deterministic simulator
/// (`crate::sim`) substitutes its own virtual-time transport. Components
/// (client cores, server shards) only ever hold a [`NetSender`], so they
/// are oblivious to which backend carries their traffic.
///
/// Contract every implementation must honor, because the consistency
/// protocol depends on it: **per-directed-link FIFO, exactly-once**
/// delivery. Cross-link ordering is unconstrained.
pub trait Transport: Send + Sync {
    /// Deliver (or schedule delivery of) one addressed message.
    fn send(&self, msg: Msg) -> Result<()>;
    /// Counters for messages/bytes by payload kind.
    fn metrics(&self) -> Arc<NetMetrics>;
}

/// Cloneable sending handle over a [`Transport`] implementation.
#[derive(Clone)]
pub struct NetSender {
    inner: Arc<dyn Transport>,
}

impl NetSender {
    /// Wrap any transport implementation in a sending handle.
    pub fn from_transport(inner: Arc<dyn Transport>) -> Self {
        NetSender { inner }
    }

    /// Send a message; delivery semantics are the backend's. Returns
    /// `Err(Disconnected)` only if the destination endpoint was dropped
    /// (normal during shutdown).
    pub fn send(&self, msg: Msg) -> Result<()> {
        self.inner.send(msg)
    }

    /// Network metrics handle (messages/bytes by kind).
    pub fn metrics(&self) -> Arc<NetMetrics> {
        self.inner.metrics()
    }
}

/// The production [`Transport`]: delivery via the shared in-process bus.
struct BusTransport {
    shared: Arc<Shared>,
}

impl Transport for BusTransport {
    fn send(&self, msg: Msg) -> Result<()> {
        let bytes = msg.payload.wire_bytes();
        self.shared.metrics.record_send(msg.payload.kind(), bytes);

        if !self.shared.delayed {
            // Ideal network: direct forward. The enqueue happens UNDER the
            // links mutex: std mpsc does not order messages from different
            // producer threads even when their sends are
            // happens-before-related, and the consistency protocol depends
            // on per-link FIFO (a ClockNotify must never overtake a batch
            // its promise covers). Serializing the enqueue restores it.
            let tx = {
                let boxes = self.shared.mailboxes.lock().unwrap();
                boxes.get(&msg.dst).cloned()
            };
            return match tx {
                Some(tx) => {
                    let dst = msg.dst;
                    let _order = self.shared.links.lock().unwrap();
                    tx.send(msg).map_err(|_| Error::Disconnected(dst))
                }
                None => Err(Error::Disconnected(msg.dst)),
            };
        }
        {
            {
                let now = Instant::now();
                let (at, seq) = {
                    let mut links = self.shared.links.lock().unwrap();
                    let link = links.entry((msg.src, msg.dst)).or_default();
                    // Serialize on the link for tx-time (bandwidth).
                    let start = link.busy_until.map_or(now, |b| b.max(now));
                    let done = start + self.shared.net.tx_time(bytes);
                    link.busy_until = Some(done);
                    // Propagation latency + jitter.
                    let jitter = if self.shared.net.jitter_us > 0 {
                        self.shared
                            .jitter_rng
                            .lock()
                            .unwrap()
                            .range_u64(0, self.shared.net.jitter_us)
                    } else {
                        0
                    };
                    let mut at = done
                        + Duration::from_micros(self.shared.net.latency_us)
                        + Duration::from_micros(jitter);
                    // FIFO floor.
                    if let Some(last) = link.last_delivery {
                        if at < last {
                            at = last;
                        }
                    }
                    link.last_delivery = Some(at);
                    // seq assigned under the links lock so per-link (at,
                    // seq) is monotone even across producer threads.
                    (at, self.shared.seq.fetch_add(1, Ordering::Relaxed))
                };
                let inject = self.shared.inject.lock().unwrap();
                match inject.as_ref() {
                    Some(tx) => tx
                        .send(Scheduled { at, seq, msg })
                        .map_err(|_| Error::Other("network dispatcher stopped".into())),
                    None => Err(Error::Other("network dispatcher stopped".into())),
                }
            }
        }
    }

    fn metrics(&self) -> Arc<NetMetrics> {
        self.shared.metrics.clone()
    }
}

/// The simulated network fabric. Create once per system; register every
/// node before spawning its thread.
pub struct Network {
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    stop_tx: Option<Sender<Scheduled>>,
}

impl Network {
    /// Build a network with the given profile. A dispatcher thread is
    /// spawned only when the profile actually delays messages.
    pub fn new(net: NetConfig) -> Self {
        Self::new_with_metrics(net, Arc::new(NetMetrics::default()))
    }

    /// Same, but recording into an externally constructed metrics handle —
    /// so the bus's counters live in a shared [`crate::metrics::Registry`]
    /// instead of a throwaway one.
    pub fn new_with_metrics(net: NetConfig, metrics: Arc<NetMetrics>) -> Self {
        let ideal = net.latency_us == 0 && net.bandwidth_bps == 0 && net.jitter_us == 0;
        let jitter_rng = Mutex::new(Rng64::seed_from_u64(net.seed));

        if ideal {
            let shared = Arc::new(Shared {
                mailboxes: Mutex::new(HashMap::new()),
                links: Mutex::new(HashMap::new()),
                jitter_rng,
                net,
                metrics,
                seq: AtomicU64::new(0),
                delayed: false,
                inject: Mutex::new(None),
            });
            return Network { shared, dispatcher: None, stop_tx: None };
        }

        let (inject_tx, inject_rx) = channel::<Scheduled>();
        let shared = Arc::new(Shared {
            mailboxes: Mutex::new(HashMap::new()),
            links: Mutex::new(HashMap::new()),
            jitter_rng,
            net,
            metrics,
            seq: AtomicU64::new(0),
            delayed: true,
            inject: Mutex::new(Some(inject_tx)),
        });

        let disp_shared = shared.clone();
        let dispatcher = std::thread::Builder::new()
            .name("net-dispatch".into())
            .spawn(move || dispatcher_loop(disp_shared, inject_rx))
            .expect("spawn net dispatcher");

        Network { shared, dispatcher: Some(dispatcher), stop_tx: None }
    }

    /// Register a node; returns its mailbox endpoint. Panics if the node is
    /// already registered (topology bug).
    pub fn register(&self, node: NodeId) -> Endpoint {
        self.registrar().register(node)
    }

    /// Remove a node's mailbox (dropping it closes the endpoint).
    pub fn deregister(&self, node: NodeId) {
        self.registrar().deregister(node)
    }

    /// A cloneable sender handle.
    pub fn sender(&self) -> NetSender {
        NetSender::from_transport(Arc::new(BusTransport { shared: self.shared.clone() }))
    }

    /// A cloneable registration handle (endpoint churn from other
    /// threads — see [`Registrar`]).
    pub fn registrar(&self) -> Registrar {
        Registrar { shared: self.shared.clone() }
    }

    /// Network metrics (messages/bytes by kind).
    pub fn metrics(&self) -> Arc<NetMetrics> {
        self.shared.metrics.clone()
    }
}

/// Cloneable registration handle: lets a supervisor thread (the
/// coordinator's failure monitor) swap a node's mailbox — deregister the
/// dead shard, register its replacement — without owning the [`Network`].
/// Sends to a deregistered node fail fast with `Error::Disconnected`;
/// they never block.
#[derive(Clone)]
pub struct Registrar {
    shared: Arc<Shared>,
}

impl Registrar {
    /// Register a node; returns its mailbox endpoint. Panics if the node
    /// is already registered (deregister the old mailbox first).
    pub fn register(&self, node: NodeId) -> Endpoint {
        let (tx, rx) = channel();
        let mut boxes = self.shared.mailboxes.lock().unwrap();
        let prev = boxes.insert(node, tx);
        assert!(prev.is_none(), "node {node} registered twice");
        Endpoint { node, rx }
    }

    /// Remove a node's mailbox (dropping it closes the endpoint).
    pub fn deregister(&self, node: NodeId) {
        self.shared.mailboxes.lock().unwrap().remove(&node);
    }

    /// A sender handle over the same fabric.
    pub fn sender(&self) -> NetSender {
        NetSender::from_transport(Arc::new(BusTransport { shared: self.shared.clone() }))
    }
}

impl Drop for Network {
    fn drop(&mut self) {
        // Sever the injection channel: once the (sole) sender is gone the
        // dispatcher drains its heap and exits; then it is safe to join.
        self.stop_tx.take();
        *self.shared.inject.lock().unwrap() = None;
        self.shared.mailboxes.lock().unwrap().clear();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(shared: Arc<Shared>, rx: Receiver<Scheduled>) {
    let mut heap: BinaryHeap<Reverse<Scheduled>> = BinaryHeap::new();
    let mut disconnected = false;
    loop {
        // Wait for either the next due message or a new injection.
        let next_due = heap.peek().map(|Reverse(s)| s.at);
        match next_due {
            None => {
                if disconnected {
                    break;
                }
                match rx.recv() {
                    Ok(s) => {
                        heap.push(Reverse(s));
                        shared.metrics.set_inflight(heap.len());
                    }
                    Err(_) => break, // all senders gone and heap empty
                }
            }
            Some(at) => {
                let now = Instant::now();
                if at > now && !disconnected {
                    match rx.recv_timeout(at - now) {
                        Ok(s) => {
                            heap.push(Reverse(s));
                            shared.metrics.set_inflight(heap.len());
                        }
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => disconnected = true,
                    }
                } else if at > now {
                    std::thread::sleep((at - now).min(Duration::from_millis(5)));
                }
            }
        }
        // Deliver everything due.
        let now = Instant::now();
        while let Some(Reverse(s)) = heap.peek() {
            if s.at > now {
                break;
            }
            let Reverse(s) = heap.pop().unwrap();
            let tx = {
                let boxes = shared.mailboxes.lock().unwrap();
                boxes.get(&s.msg.dst).cloned()
            };
            if let Some(tx) = tx {
                shared.metrics.record_deliver(s.msg.payload.kind());
                let _ = tx.send(s.msg); // dst may have shut down; fine
            }
            shared.metrics.set_inflight(heap.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::msg::Payload;
    use crate::types::{NodeId, ProcId, ShardId};

    fn msg(src: NodeId, dst: NodeId, clock: u32) -> Msg {
        Msg { src, dst, payload: Payload::MinClock { shard: ShardId(0), clock } }
    }

    #[test]
    fn ideal_network_direct_delivery() {
        let net = Network::new(NetConfig::default());
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let ep = net.register(b);
        let _epa = net.register(a);
        let tx = net.sender();
        for i in 0..100 {
            tx.send(msg(a, b, i)).unwrap();
        }
        for i in 0..100 {
            match ep.recv().unwrap().payload {
                Payload::MinClock { clock, .. } => assert_eq!(clock, i),
                _ => panic!("wrong payload"),
            }
        }
    }

    #[test]
    fn send_to_unregistered_is_disconnected() {
        let net = Network::new(NetConfig::default());
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(9));
        let _epa = net.register(a);
        let tx = net.sender();
        assert!(matches!(tx.send(msg(a, b, 0)), Err(Error::Disconnected(_))));
    }

    #[test]
    fn delayed_network_preserves_fifo_per_link() {
        let net = Network::new(NetConfig {
            latency_us: 200,
            bandwidth_bps: 0,
            jitter_us: 150, // jitter large vs latency: would reorder w/o floor
            seed: 42,
        });
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let ep = net.register(b);
        let _epa = net.register(a);
        let tx = net.sender();
        for i in 0..200 {
            tx.send(msg(a, b, i)).unwrap();
        }
        for i in 0..200 {
            let m = ep.recv_timeout(Duration::from_secs(5)).unwrap().expect("msg");
            match m.payload {
                Payload::MinClock { clock, .. } => assert_eq!(clock, i, "FIFO violated"),
                _ => panic!("wrong payload"),
            }
        }
    }

    #[test]
    fn latency_actually_delays() {
        let net = Network::new(NetConfig {
            latency_us: 20_000, // 20 ms
            bandwidth_bps: 0,
            jitter_us: 0,
            seed: 0,
        });
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let ep = net.register(b);
        let _epa = net.register(a);
        let tx = net.sender();
        let t0 = Instant::now();
        tx.send(msg(a, b, 0)).unwrap();
        let _ = ep.recv_timeout(Duration::from_secs(5)).unwrap().expect("msg");
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(18), "arrived too early: {dt:?}");
    }

    #[test]
    fn bandwidth_serializes_large_messages() {
        // 1 MB/s; two 100 KB messages need ≥ ~200 ms total.
        let net = Network::new(NetConfig {
            latency_us: 0,
            bandwidth_bps: 1_000_000,
            jitter_us: 0,
            seed: 0,
        });
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let ep = net.register(b);
        let _epa = net.register(a);
        let tx = net.sender();
        let big = Msg {
            src: a,
            dst: b,
            payload: Payload::PullReply {
                table: crate::table::TableId(0),
                row: crate::table::RowId(0),
                data: std::sync::Arc::new(crate::table::RowData::Dense(vec![0.0; 25_000])), // 100 KB
                clock: 0,
                worker: crate::types::WorkerId(0),
                trace: crate::trace::TraceCtx::NONE,
            },
        };
        let t0 = Instant::now();
        tx.send(big.clone()).unwrap();
        tx.send(big).unwrap();
        for _ in 0..2 {
            ep.recv_timeout(Duration::from_secs(5)).unwrap().expect("msg");
        }
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(150), "bandwidth not enforced: {dt:?}");
    }

    #[test]
    fn deregistered_node_send_fails_fast() {
        let net = Network::new(NetConfig::default());
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let _epb = net.register(b);
        let _epa = net.register(a);
        let tx = net.sender();
        tx.send(msg(a, b, 0)).unwrap();
        net.deregister(b);
        // An error, immediately — never a hang on a dead destination.
        assert!(matches!(tx.send(msg(a, b, 1)), Err(Error::Disconnected(_))));
    }

    #[test]
    fn reregistering_a_node_swaps_its_mailbox() {
        let net = Network::new(NetConfig::default());
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let _epa = net.register(a);
        let ep_old = net.register(b);
        let tx = net.sender();
        tx.send(msg(a, b, 7)).unwrap();
        // Respawn: deregister the dead incarnation, register a fresh one.
        net.deregister(b);
        let ep_new = net.register(b);
        tx.send(msg(a, b, 8)).unwrap();
        // Old mailbox kept the pre-churn message; the new one only sees
        // post-churn traffic.
        match ep_old.try_recv().expect("old mailbox retains its message").payload {
            Payload::MinClock { clock, .. } => assert_eq!(clock, 7),
            _ => panic!("wrong payload"),
        }
        match ep_new.try_recv().expect("new mailbox receives").payload {
            Payload::MinClock { clock, .. } => assert_eq!(clock, 8),
            _ => panic!("wrong payload"),
        }
        assert!(ep_new.try_recv().is_none());
    }

    #[test]
    fn registrar_churns_endpoints_from_a_clone() {
        let net = Network::new(NetConfig::default());
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let _epa = net.register(a);
        let _epb = net.register(b);
        let reg = net.registrar();
        let tx = reg.sender();
        let done = std::thread::spawn(move || {
            reg.deregister(b);
            let ep = reg.register(b);
            (reg, ep)
        })
        .join()
        .unwrap();
        tx.send(msg(a, b, 3)).unwrap();
        match done.1.try_recv().expect("respawned mailbox receives").payload {
            Payload::MinClock { clock, .. } => assert_eq!(clock, 3),
            _ => panic!("wrong payload"),
        }
    }

    #[test]
    fn metrics_count_sends() {
        let net = Network::new(NetConfig::default());
        let a = NodeId::Client(ProcId(0));
        let b = NodeId::Server(ShardId(0));
        let _ep = net.register(b);
        let _epa = net.register(a);
        let tx = net.sender();
        for i in 0..7 {
            tx.send(msg(a, b, i)).unwrap();
        }
        assert_eq!(net.metrics().sends("min_clock"), 7);
        assert!(net.metrics().bytes_sent() > 0);
    }
}
