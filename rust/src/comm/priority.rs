//! Magnitude-priority update scheduling (paper §4.2).
//!
//! "Messages are sent out based on their priorities ... We by default
//! prioritize updates with larger magnitude as they are more likely to
//! contribute to convergence."
//!
//! [`UpdateQueue`] is the client-side egress queue: pending row-deltas,
//! pre-aggregated per `(table is implicit, row)` key, drained either in
//! FIFO order or largest-magnitude-first. Aggregation per row also gives
//! the batching win the paper describes: ten `Inc`s to one row leave as
//! one wire delta.

use std::collections::HashMap;

use crate::table::{RowId, RowUpdate};

/// Draining order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainOrder {
    /// Oldest-enqueued row first.
    Fifo,
    /// Largest accumulated L∞ magnitude first (the paper's default).
    Magnitude,
}

/// Pending, per-row-aggregated updates for one table awaiting flush.
pub struct UpdateQueue {
    /// row → (aggregated delta, enqueue sequence of first touch)
    pending: HashMap<RowId, (RowUpdate, u64)>,
    next_seq: u64,
    order: DrainOrder,
    /// How many drained rows overtook an older pending row (magnitude
    /// priority reordering the egress stream); see [`Self::take_reorders`].
    reorders: u64,
}

impl UpdateQueue {
    /// New queue with the given drain order.
    pub fn new(order: DrainOrder) -> Self {
        UpdateQueue { pending: HashMap::new(), next_seq: 0, order, reorders: 0 }
    }

    /// Add a delta for `row`, merging with any pending delta for that row.
    pub fn push(&mut self, row: RowId, update: RowUpdate) {
        let seq = self.next_seq;
        match self.pending.get_mut(&row) {
            Some((agg, _)) => agg.merge(&update),
            None => {
                self.pending.insert(row, (update, seq));
                self.next_seq += 1;
            }
        }
    }

    /// Number of distinct pending rows.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Read the pending (not yet drained) aggregated delta for `row` —
    /// the read-my-writes overlay for unsent updates.
    pub fn get(&self, row: RowId) -> Option<&RowUpdate> {
        self.pending.get(&row).map(|(u, _)| u)
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Remove and return up to `max_rows` pending row-deltas in drain
    /// order. Zero-deltas (increments that cancelled out) are dropped
    /// rather than shipped.
    pub fn drain(&mut self, max_rows: usize) -> Vec<(RowId, RowUpdate)> {
        if self.pending.is_empty() || max_rows == 0 {
            return Vec::new();
        }
        let mut keys: Vec<(RowId, f32, u64)> = self
            .pending
            .iter()
            .map(|(r, (u, seq))| (*r, u.magnitude(), *seq))
            .collect();
        match self.order {
            DrainOrder::Fifo => keys.sort_by_key(|&(_, _, seq)| seq),
            DrainOrder::Magnitude => keys.sort_by(|a, b| {
                // Largest magnitude first; tie-break FIFO for determinism.
                b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.2.cmp(&b.2))
            }),
        }
        let take = max_rows.min(keys.len());
        if self.order == DrainOrder::Magnitude {
            // Count overtakes: an emitted row whose first-touch sequence is
            // newer than some row emitted after it jumped the FIFO queue.
            let mut min_after = u64::MAX;
            for &(_, _, seq) in keys[..take].iter().rev() {
                if seq > min_after {
                    self.reorders += 1;
                }
                min_after = min_after.min(seq);
            }
        }
        let mut out = Vec::with_capacity(take);
        for (row, _, _) in keys.into_iter().take(max_rows) {
            if let Some((u, _)) = self.pending.remove(&row) {
                if !u.is_zero() {
                    out.push((row, u));
                }
            }
        }
        out
    }

    /// Iterate pending aggregated row-deltas (diagnostics).
    pub fn iter(&self) -> impl Iterator<Item = (RowId, &RowUpdate)> + '_ {
        self.pending.iter().map(|(r, (u, _))| (*r, u))
    }

    /// Drain everything (clock-boundary flush).
    pub fn drain_all(&mut self) -> Vec<(RowId, RowUpdate)> {
        self.drain(usize::MAX)
    }

    /// Take (and reset) the number of drain-order overtakes accumulated
    /// since the last call — feeds `client_egress_reorders_total`.
    pub fn take_reorders(&mut self) -> u64 {
        std::mem::take(&mut self.reorders)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merges_same_row() {
        let mut q = UpdateQueue::new(DrainOrder::Fifo);
        q.push(RowId(1), RowUpdate::single(0, 1.0));
        q.push(RowId(1), RowUpdate::single(0, 2.0));
        q.push(RowId(1), RowUpdate::single(3, -1.0));
        assert_eq!(q.len(), 1);
        let got = q.drain_all();
        assert_eq!(got.len(), 1);
        let (row, u) = &got[0];
        assert_eq!(*row, RowId(1));
        let pairs: Vec<_> = u.iter_nonzero().collect();
        assert_eq!(pairs, vec![(0, 3.0), (3, -1.0)]);
        assert!(q.is_empty());
    }

    #[test]
    fn fifo_order_is_first_touch() {
        let mut q = UpdateQueue::new(DrainOrder::Fifo);
        q.push(RowId(5), RowUpdate::single(0, 0.1));
        q.push(RowId(2), RowUpdate::single(0, 9.0));
        q.push(RowId(5), RowUpdate::single(0, 0.1)); // merge, keeps seq
        let got = q.drain_all();
        let rows: Vec<u64> = got.iter().map(|(r, _)| r.0).collect();
        assert_eq!(rows, vec![5, 2]);
    }

    #[test]
    fn magnitude_order_puts_big_first() {
        let mut q = UpdateQueue::new(DrainOrder::Magnitude);
        q.push(RowId(1), RowUpdate::single(0, 0.1));
        q.push(RowId(2), RowUpdate::single(0, 5.0));
        q.push(RowId(3), RowUpdate::single(0, -9.0));
        let got = q.drain_all();
        let rows: Vec<u64> = got.iter().map(|(r, _)| r.0).collect();
        assert_eq!(rows, vec![3, 2, 1]);
    }

    #[test]
    fn drain_respects_max_and_keeps_rest() {
        let mut q = UpdateQueue::new(DrainOrder::Magnitude);
        for i in 0..10u64 {
            q.push(RowId(i), RowUpdate::single(0, i as f32));
        }
        let first = q.drain(3);
        assert_eq!(first.len(), 3);
        assert_eq!(first[0].0, RowId(9));
        assert_eq!(q.len(), 7);
        // zero-magnitude row 0 is dropped on the final drain
        let rest = q.drain_all();
        assert_eq!(rest.len(), 6, "row 0 had delta 0.0 and must be dropped");
    }

    #[test]
    fn reorders_counted_for_magnitude_only() {
        let mut q = UpdateQueue::new(DrainOrder::Magnitude);
        q.push(RowId(1), RowUpdate::single(0, 0.1)); // oldest, smallest
        q.push(RowId(2), RowUpdate::single(0, 5.0));
        q.push(RowId(3), RowUpdate::single(0, -9.0));
        q.drain_all(); // emit order 3, 2, 1: rows 3 and 2 overtake row 1
        assert_eq!(q.take_reorders(), 2);
        assert_eq!(q.take_reorders(), 0, "take resets the counter");

        let mut f = UpdateQueue::new(DrainOrder::Fifo);
        f.push(RowId(1), RowUpdate::single(0, 0.1));
        f.push(RowId(2), RowUpdate::single(0, 5.0));
        f.drain_all();
        assert_eq!(f.take_reorders(), 0, "FIFO never reorders");
    }

    #[test]
    fn cancelled_updates_not_shipped() {
        let mut q = UpdateQueue::new(DrainOrder::Fifo);
        q.push(RowId(1), RowUpdate::single(0, 1.0));
        q.push(RowId(1), RowUpdate::single(0, -1.0));
        assert_eq!(q.drain_all().len(), 0);
    }
}
