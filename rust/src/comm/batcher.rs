//! Wire-batch assembly (paper §4.2).
//!
//! "Asynchronous system tends to congest the network with large volume of
//! messages. Our client and server thus batch messages to achieve high
//! throughput."
//!
//! The [`Batcher`] slices a drained update list into per-shard
//! [`PushBatch`]es: each row belongs to exactly one shard (hash
//! partitioning, §4.1), so one drain typically becomes `num_shards` wire
//! messages regardless of how many `Inc`s it covers. Batch ids are
//! per-origin monotone, which (with FIFO links) gives the per-worker FIFO
//! update visibility the consistency models assume.

use std::collections::HashMap;
use std::sync::Arc;

use crate::comm::msg::PushBatch;
use crate::table::{RowId, RowUpdate, TableDesc};
use crate::trace::TraceCtx;
use crate::types::{Clock, ProcId, ShardId};

/// Assembles per-shard push batches with monotone batch ids.
pub struct Batcher {
    origin: ProcId,
    next_batch_id: u64,
    max_batch_updates: usize,
}

impl Batcher {
    /// New batcher for updates originating at `origin`.
    pub fn new(origin: ProcId, max_batch_updates: usize) -> Self {
        Batcher { origin, next_batch_id: 0, max_batch_updates: max_batch_updates.max(1) }
    }

    /// The id the *next* produced batch will carry.
    pub fn next_id(&self) -> u64 {
        self.next_batch_id
    }

    /// Split row-deltas for one table into per-shard batches, each at most
    /// `max_batch_updates` rows, stamped with `clock`. Returns
    /// `(shard, batch)` pairs; batch ids increase in emission order. `now`
    /// (µs on the trace clock) is the seal time minted into each batch's
    /// trace context.
    pub fn make_batches(
        &mut self,
        desc: &TableDesc,
        num_shards: u32,
        updates: Vec<(RowId, RowUpdate)>,
        clock: Clock,
        now: u64,
    ) -> Vec<(ShardId, PushBatch)> {
        if updates.is_empty() {
            return Vec::new();
        }
        let mut by_shard: HashMap<ShardId, Vec<(RowId, RowUpdate)>> = HashMap::new();
        for (row, u) in updates {
            by_shard.entry(desc.shard_of(row, num_shards)).or_default().push((row, u));
        }
        // Deterministic emission order (shard id) so batch ids are stable
        // across runs with the same input — matters for trace comparison.
        let mut shards: Vec<ShardId> = by_shard.keys().copied().collect();
        shards.sort();

        let mut out = Vec::new();
        for shard in shards {
            let rows = by_shard.remove(&shard).unwrap();
            for chunk in rows.chunks(self.max_batch_updates) {
                let batch = PushBatch {
                    table: desc.id,
                    origin: self.origin,
                    batch_id: self.next_batch_id,
                    updates: Arc::new(chunk.to_vec()),
                    clock,
                    // Stamped with the sender's believed shard epoch at send
                    // time (the batcher doesn't track incarnations).
                    epoch: 0,
                    // (origin, batch_id) is globally unique, so the minted
                    // id is too; retransmissions reuse it.
                    trace: TraceCtx::mint(
                        1,
                        self.origin.0 as u64,
                        self.next_batch_id,
                        desc.id.0 as u64,
                        now,
                    ),
                };
                self.next_batch_id += 1;
                out.push((shard, batch));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::table::{RowKind, TableId};

    fn desc() -> TableDesc {
        TableDesc {
            id: TableId(0),
            num_rows: 1024,
            row_width: 4,
            row_kind: RowKind::Dense,
            policy: PolicyConfig::Cap { staleness: 1 },
        }
    }

    #[test]
    fn batches_route_rows_to_owning_shard() {
        let d = desc();
        let mut b = Batcher::new(ProcId(0), 100);
        let ups: Vec<_> = (0..200u64).map(|r| (RowId(r), RowUpdate::single(0, 1.0))).collect();
        let batches = b.make_batches(&d, 4, ups, 3, 0);
        assert!(!batches.is_empty());
        let mut seen_rows = 0;
        for (shard, batch) in &batches {
            assert_eq!(batch.clock, 3);
            for (row, _) in batch.updates.iter() {
                assert_eq!(d.shard_of(*row, 4), *shard, "row routed to wrong shard");
                seen_rows += 1;
            }
        }
        assert_eq!(seen_rows, 200);
    }

    #[test]
    fn batch_ids_are_monotone_across_calls() {
        let d = desc();
        let mut b = Batcher::new(ProcId(1), 2);
        let mk = |n: u64| -> Vec<_> {
            (0..n).map(|r| (RowId(r), RowUpdate::single(0, 1.0))).collect()
        };
        let first = b.make_batches(&d, 2, mk(5), 0, 0);
        let second = b.make_batches(&d, 2, mk(3), 1, 0);
        let mut ids: Vec<u64> =
            first.iter().chain(second.iter()).map(|(_, b)| b.batch_id).collect();
        let sorted = {
            let mut s = ids.clone();
            s.sort();
            s
        };
        assert_eq!(ids.len(), sorted.len());
        ids.dedup();
        assert_eq!(ids.len(), sorted.len(), "batch ids must be unique");
        assert_eq!(b.next_id(), (first.len() + second.len()) as u64);
    }

    #[test]
    fn max_batch_updates_respected() {
        let d = desc();
        let mut b = Batcher::new(ProcId(0), 3);
        let ups: Vec<_> = (0..10u64).map(|r| (RowId(r), RowUpdate::single(0, 1.0))).collect();
        for (_, batch) in b.make_batches(&d, 1, ups, 0, 0) {
            assert!(batch.updates.len() <= 3);
        }
    }

    #[test]
    fn minted_trace_ids_unique_and_stamped() {
        let d = desc();
        let mut b = Batcher::new(ProcId(2), 2);
        let ups: Vec<_> = (0..6u64).map(|r| (RowId(r), RowUpdate::single(0, 1.0))).collect();
        let batches = b.make_batches(&d, 2, ups, 1, 77);
        let ids: std::collections::HashSet<u64> =
            batches.iter().map(|(_, b)| b.trace.id).collect();
        assert_eq!(ids.len(), batches.len(), "one trace id per batch");
        assert!(!ids.contains(&0));
        assert!(batches.iter().all(|(_, b)| b.trace.at_us == 77));
    }

    #[test]
    fn empty_input_no_batches() {
        let d = desc();
        let mut b = Batcher::new(ProcId(0), 8);
        assert!(b.make_batches(&d, 4, vec![], 0, 0).is_empty());
        assert_eq!(b.next_id(), 0);
    }
}
