//! Communication substrate.
//!
//! The paper ran Petuum PS over ZeroMQ on an 8-node 40 GbE cluster. This
//! reproduction's "network" is an in-process message bus ([`bus::Network`])
//! whose links have configurable latency, bandwidth and jitter
//! ([`crate::config::NetConfig`]) and which preserves per-link FIFO order —
//! the property the paper's FIFO-consistency guarantee rests on (§2, citing
//! PRAM [Lipton & Sandberg]). Server shards and client processes are
//! threads; a slow link or a saturated one produces exactly the delayed /
//! backlogged visibility the bounded-asynchronous models must tolerate.
//!
//! Sub-modules:
//! * [`msg`] — wire message types (client push/pull, server push, acks).
//! * [`bus`] — the network itself: endpoints, delayed delivery, FIFO links.
//! * [`batcher`] — update batching (paper §4.2 "client and server batch
//!   messages to achieve high throughput").
//! * [`priority`] — magnitude-priority scheduling of outbound updates
//!   (paper §4.2 "we by default prioritize updates with larger magnitude").

pub mod batcher;
pub mod bus;
pub mod msg;
pub mod priority;

pub use batcher::Batcher;
pub use bus::{Endpoint, NetSender, Network, Registrar, Transport};
pub use msg::{Msg, Payload, PushBatch, ServerPushBatch};
pub use priority::UpdateQueue;
