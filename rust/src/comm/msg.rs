//! Wire message types.
//!
//! Petuum PS uses three kinds of network communication (paper §4.3):
//! **Client Push** (client sends batched updates to a server), **Client
//! Pull** (client fetches a row from a server) and **Server Push** (server
//! forwards batched updates to the clients caching the affected rows).
//! On top of those, the bounded-asynchronous models need acknowledgement
//! traffic so the system can decide when an update has become *visible to
//! all workers* (the event that unblocks VAP writers): [`Payload::PushAck`]
//! and [`Payload::VisibilityAck`]. Clock notifications drive the server's
//! process vector clock.


use std::sync::Arc;

use crate::table::{RowData, RowId, RowUpdate, TableId};
use crate::trace::TraceCtx;
use crate::types::{Clock, NodeId, ProcId, ShardId, WorkerId};

/// A batch of updates pushed from a client process to the owning shard.
///
/// The batch is the unit of visibility tracking: the origin client assigns
/// a process-unique `batch_id`; once every *other* client process has acked
/// the corresponding server push, the server reports the batch globally
/// visible back to the origin.
#[derive(Debug, Clone)]
pub struct PushBatch {
    /// Table the updates belong to.
    pub table: TableId,
    /// Originating client process.
    pub origin: ProcId,
    /// Process-unique, monotonically increasing batch id (FIFO per origin).
    pub batch_id: u64,
    /// Row-granular deltas, pre-aggregated per row by the batcher. Shared
    /// (`Arc`) so the WAL, the visibility tracker's held queue and the
    /// fan-out to forwarded server pushes reference one allocation instead
    /// of deep-cloning the update list on every hop. Legal because the
    /// in-process bus moves Rust values — nothing serializes the batch
    /// except the (reference-taking) persistence codec.
    pub updates: Arc<Vec<(RowId, RowUpdate)>>,
    /// Clock timestamp of the newest update in the batch (updates generated
    /// in `(c-1, c]` are stamped `c`, paper §2.1).
    pub clock: Clock,
    /// Incarnation epoch of the destination shard as believed by the sender.
    /// A recovered shard bumps its epoch and fences off batches stamped with
    /// an older one: they were sent before the sender resynced, and accepting
    /// them could break per-origin FIFO (a fresh batch overtaking a pending
    /// retransmission of an older one).
    pub epoch: u32,
    /// Causal trace context minted at batch-seal time. Follows the batch
    /// through retransmissions and the forwarded [`ServerPushBatch`] so
    /// every layer's span carries the same trace id.
    pub trace: TraceCtx,
}

impl PushBatch {
    /// Approximate wire size (drives the bandwidth simulation). The trace
    /// context costs 16 bytes.
    pub fn wire_bytes(&self) -> usize {
        48 + self.updates.iter().map(|(_, u)| 12 + u.wire_bytes()).sum::<usize>()
    }
}

/// A batch of (foreign) updates pushed from a server shard to a caching
/// client process, so its process cache stays fresh without polling.
#[derive(Debug, Clone)]
pub struct ServerPushBatch {
    /// Table the updates belong to.
    pub table: TableId,
    /// The process that originally produced the updates.
    pub origin: ProcId,
    /// The origin's batch id (for the receiver's ack).
    pub batch_id: u64,
    /// Row deltas to apply to the process cache. Shared with the origin
    /// `PushBatch`: forwarding to `P` processes clones the `Arc`, not the
    /// update list.
    pub updates: Arc<Vec<(RowId, RowUpdate)>>,
    /// The shard's min process clock at forward time; receiving caches may
    /// raise row freshness to this value.
    pub min_clock: Clock,
    /// The origin batch's trace context, carried through the fan-out.
    pub trace: TraceCtx,
}

impl ServerPushBatch {
    /// Approximate wire size (16 of which is the trace context).
    pub fn wire_bytes(&self) -> usize {
        48 + self.updates.iter().map(|(_, u)| 12 + u.wire_bytes()).sum::<usize>()
    }
}

/// Every message body that can cross the (simulated) network.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Client → server: batched updates (Client Push).
    PushUpdates(PushBatch),
    /// Client → server: fetch a row, blocking server-side until the shard's
    /// min process clock reaches `needed_clock` (Client Pull). `worker` is
    /// echoed back so the client library can wake the right thread.
    PullRow {
        /// Table to read from.
        table: TableId,
        /// Row to fetch.
        row: RowId,
        /// Reply may be deferred until the shard min clock ≥ this.
        needed_clock: Clock,
        /// Requesting worker (echoed in the reply).
        worker: WorkerId,
        /// Trace context minted at request-issue time; the shard echoes it
        /// in the reply so the client can close the pull span without a
        /// request table.
        trace: TraceCtx,
    },
    /// Server → client: full-row reply to a pull.
    PullReply {
        /// Table the row belongs to.
        table: TableId,
        /// The row id.
        row: RowId,
        /// Row value snapshot. Shared with the shard's store (copy-on-write
        /// rows): serving a pull clones the `Arc`, not the row.
        data: Arc<RowData>,
        /// Freshness: shard min process clock when the snapshot was taken.
        clock: Clock,
        /// The worker that asked.
        worker: WorkerId,
        /// Echo of the request's trace context.
        trace: TraceCtx,
    },
    /// Client → every server shard: this process's min thread clock moved.
    /// A notification is a *promise*: no future update from `proc` will be
    /// stamped ≤ `clock`. Like pushes it is epoch-fenced — a notification
    /// sent before the process resynced with a recovered shard must not be
    /// honoured, because retransmissions of older-stamped updates may still
    /// be outstanding.
    ClockNotify {
        /// Reporting process.
        proc: ProcId,
        /// New min clock over the process's worker threads.
        clock: Clock,
        /// Destination-shard incarnation epoch as believed by the sender.
        epoch: u32,
    },
    /// Server → caching client: forwarded foreign updates (Server Push).
    ServerPush(ServerPushBatch),
    /// Client → server: ack of a [`Payload::ServerPush`] — the receiving
    /// process has applied origin's batch to its process cache.
    PushAck {
        /// Table concerned.
        table: TableId,
        /// Origin process of the acked batch.
        origin: ProcId,
        /// The acked batch id.
        batch_id: u64,
        /// The acking process.
        by: ProcId,
    },
    /// Server → origin client: the batch is now visible to all processes.
    /// This is the event that releases VAP-blocked writers.
    VisibilityAck {
        /// Table concerned.
        table: TableId,
        /// The now-globally-visible batch.
        batch_id: u64,
    },
    /// Server → all clients: the shard's min process clock advanced. Client
    /// caches bump freshness of rows owned by that shard and wake
    /// CAP/SSP-blocked readers.
    MinClock {
        /// Reporting shard.
        shard: ShardId,
        /// New min process clock on that shard.
        clock: Clock,
    },
    /// Coordinator → shard: liveness probe. A shard that misses enough
    /// probe deadlines is declared dead and respawned from its persisted
    /// state (checkpoint + WAL replay).
    Ping {
        /// Probe sequence number, echoed in the [`Payload::Pong`].
        seq: u64,
    },
    /// Shard → coordinator: liveness probe reply.
    Pong {
        /// Replying shard.
        shard: ShardId,
        /// Echo of the probe's sequence number.
        seq: u64,
    },
    /// Recovered shard → client: re-solicit a possibly-lost
    /// [`Payload::PushAck`]. The client re-acks iff it already applied the
    /// batch; the server's ack tracking is set-based, so a duplicate re-ack
    /// is harmless.
    AckProbe {
        /// Table concerned.
        table: TableId,
        /// Origin process of the batch awaiting acks.
        origin: ProcId,
        /// The batch id awaiting acks.
        batch_id: u64,
    },
    /// Recovered shard → all clients: the shard is back at a new incarnation
    /// epoch. Clients resync: retransmit unechoed batches for this shard (in
    /// batch-id order, original clocks, new epoch), then re-promise their
    /// clock, then re-issue in-flight pulls.
    ShardRecovered {
        /// The recovered shard.
        shard: ShardId,
        /// Its new incarnation epoch.
        epoch: u32,
    },
    /// Orderly shutdown of the receiving event loop.
    Shutdown,
}

impl Payload {
    /// Approximate wire size in bytes (bandwidth simulation). Control
    /// messages are costed at a small fixed size.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::PushUpdates(b) => b.wire_bytes(),
            Payload::ServerPush(b) => b.wire_bytes(),
            Payload::PullReply { data, .. } => 48 + data.wire_bytes(),
            Payload::PullRow { .. } => 48,
            Payload::ClockNotify { .. }
            | Payload::PushAck { .. }
            | Payload::VisibilityAck { .. }
            | Payload::MinClock { .. }
            | Payload::Ping { .. }
            | Payload::Pong { .. }
            | Payload::AckProbe { .. }
            | Payload::ShardRecovered { .. }
            | Payload::Shutdown => 16,
        }
    }

    /// Short tag for metrics/trace.
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::PushUpdates(_) => "push",
            Payload::PullRow { .. } => "pull",
            Payload::PullReply { .. } => "pull_reply",
            Payload::ClockNotify { .. } => "clock",
            Payload::ServerPush(_) => "server_push",
            Payload::PushAck { .. } => "push_ack",
            Payload::VisibilityAck { .. } => "vis_ack",
            Payload::MinClock { .. } => "min_clock",
            Payload::Ping { .. } => "ping",
            Payload::Pong { .. } => "pong",
            Payload::AckProbe { .. } => "ack_probe",
            Payload::ShardRecovered { .. } => "recovered",
            Payload::Shutdown => "shutdown",
        }
    }
}

/// Every wire kind, in a fixed order: the index of a kind here is its
/// slot in the per-kind metric arrays ([`crate::metrics::NetMetrics`]).
pub const KINDS: [&str; 13] = [
    "push",
    "pull",
    "pull_reply",
    "clock",
    "server_push",
    "push_ack",
    "vis_ack",
    "min_clock",
    "ping",
    "pong",
    "ack_probe",
    "recovered",
    "shutdown",
];

/// Slot of a [`Payload::kind`] tag in [`KINDS`]. Panics on an unknown
/// tag (the set is closed; a miss is a programmer error).
pub fn kind_index(kind: &str) -> usize {
    KINDS.iter().position(|k| *k == kind).unwrap_or_else(|| panic!("unknown wire kind {kind}"))
}

/// An addressed message on the bus.
#[derive(Debug, Clone)]
pub struct Msg {
    /// Sender endpoint.
    pub src: NodeId,
    /// Destination endpoint.
    pub dst: NodeId,
    /// Body.
    pub payload: Payload,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_grow_with_content() {
        let small = PushBatch {
            table: TableId(0),
            origin: ProcId(0),
            batch_id: 0,
            updates: Arc::new(vec![(RowId(0), RowUpdate::single(0, 1.0))]),
            clock: 0,
            epoch: 0,
            trace: TraceCtx::NONE,
        };
        let big = PushBatch {
            updates: Arc::new(
                (0..100).map(|i| (RowId(i), RowUpdate::Dense(vec![1.0; 64]))).collect(),
            ),
            ..small.clone()
        };
        assert!(big.wire_bytes() > small.wire_bytes() * 50);
        assert!(Payload::PushUpdates(small).wire_bytes() > Payload::Shutdown.wire_bytes());
    }

    #[test]
    fn kinds_cover_all_variants() {
        let kinds = [
            Payload::Shutdown.kind(),
            Payload::MinClock { shard: ShardId(0), clock: 1 }.kind(),
            Payload::ClockNotify { proc: ProcId(0), clock: 1, epoch: 0 }.kind(),
            Payload::VisibilityAck { table: TableId(0), batch_id: 1 }.kind(),
            Payload::Ping { seq: 0 }.kind(),
            Payload::Pong { shard: ShardId(0), seq: 0 }.kind(),
            Payload::AckProbe { table: TableId(0), origin: ProcId(0), batch_id: 1 }.kind(),
            Payload::ShardRecovered { shard: ShardId(0), epoch: 1 }.kind(),
        ];
        let set: std::collections::HashSet<_> = kinds.iter().collect();
        assert_eq!(set.len(), kinds.len());
    }

    #[test]
    fn kind_index_is_total_over_kinds() {
        for (i, k) in KINDS.iter().enumerate() {
            assert_eq!(kind_index(k), i);
        }
        assert_eq!(kind_index(Payload::Shutdown.kind()), KINDS.len() - 1);
    }

    #[test]
    #[should_panic(expected = "unknown wire kind")]
    fn kind_index_rejects_unknown() {
        kind_index("nope");
    }
}
