//! Seedable PRNG + distributions (std-only; the build is offline and the
//! `rand` family is unavailable, so we carry our own well-tested
//! generators).
//!
//! * [`Rng64`] — xoshiro256++ (Blackman/Vigna), seeded through SplitMix64;
//!   fast, 2^256−1 period, passes BigCrush. Deterministic across runs and
//!   platforms — every synthetic dataset and every experiment seed in
//!   this repo flows through it.
//! * Normal samples via Marsaglia polar; Gamma via Marsaglia–Tsang
//!   (with the α<1 boost); Dirichlet by normalized Gammas.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng64 {
    /// Seed deterministically (any u64, including 0).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng64 {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform usize in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform u64 in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi, "empty range [{lo},{hi})");
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Marsaglia polar method).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Gamma(shape α, scale 1) via Marsaglia–Tsang, boosted for α < 1.
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        assert!(alpha > 0.0, "gamma shape must be > 0");
        if alpha < 1.0 {
            // boost: G(α) = G(α+1) · U^(1/α)
            let g = self.gamma(alpha + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(α) over k categories.
    pub fn dirichlet(&mut self, k: usize, alpha: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let s: f64 = v.iter().sum();
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::seed_from_u64(42);
        let mut b = Rng64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng64::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.below(7);
            assert!(y < 7);
            let z = r.range(3, 9);
            assert!((3..9).contains(&z));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng64::seed_from_u64(2);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::seed_from_u64(3);
        let n = 100_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng64::seed_from_u64(4);
        for &alpha in &[0.3, 1.0, 2.5, 10.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(alpha)).sum::<f64>() / n as f64;
            assert!(
                (mean - alpha).abs() < alpha * 0.1 + 0.02,
                "alpha={alpha} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng64::seed_from_u64(5);
        let d = r.dirichlet(16, 0.1);
        assert_eq!(d.len(), 16);
        let s: f64 = d.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(d.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng64::seed_from_u64(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>(), "astronomically unlikely");
    }
}
