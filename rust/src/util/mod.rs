//! Small shared utilities: deadlines for blocking waits, a seeded RNG
//! (std-only, offline build), a mini property-testing harness, and simple
//! stats used by benches and apps.

pub mod quickprop;
pub mod rng;

pub use rng::Rng64;

use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// A wall-clock deadline for a blocking consistency wait. Waits in the
/// client library are always bounded: an unbounded wait turns a dead peer
/// into a hang, and the paper's models are exactly about *bounded* delay.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    limit: Duration,
}

impl Deadline {
    /// Deadline `limit_ms` milliseconds from now.
    pub fn after_ms(limit_ms: u64) -> Self {
        Deadline { start: Instant::now(), limit: Duration::from_millis(limit_ms) }
    }

    /// Remaining time, or an error naming `what` if expired.
    pub fn remaining(&self, what: &str) -> Result<Duration> {
        let elapsed = self.start.elapsed();
        if elapsed >= self.limit {
            Err(Error::WaitTimeout { what: what.to_string(), waited_ms: elapsed.as_millis() as u64 })
        } else {
            Ok(self.limit - elapsed)
        }
    }

    /// Time waited so far.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Online mean/max accumulator used in bench reports.
#[derive(Debug, Clone, Default)]
pub struct RunningStat {
    n: u64,
    sum: f64,
    max: f64,
    min: f64,
}

impl RunningStat {
    /// Add an observation.
    pub fn add(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    /// Count of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Max (0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Min (0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires() {
        let d = Deadline::after_ms(0);
        std::thread::sleep(Duration::from_millis(2));
        assert!(d.remaining("x").is_err());
    }

    #[test]
    fn deadline_remaining_shrinks() {
        let d = Deadline::after_ms(10_000);
        let r1 = d.remaining("x").unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let r2 = d.remaining("x").unwrap();
        assert!(r2 < r1);
    }

    #[test]
    fn running_stat() {
        let mut s = RunningStat::default();
        assert_eq!(s.mean(), 0.0);
        for x in [1.0, 2.0, 3.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.max(), 3.0);
        assert_eq!(s.min(), 1.0);
    }
}
