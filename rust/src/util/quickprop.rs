//! A miniature property-testing harness (proptest is unavailable in the
//! offline build). Runs a property over `cases` randomized inputs from a
//! seeded [`super::Rng64`]; on failure it reports the failing case index
//! and seed so the case can be replayed exactly.
//!
//! ```no_run
//! use bapps::util::quickprop::forall;
//! forall(100, 0xFEED, |rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! (`no_run`: doctest binaries don't inherit the xla rpath; the same
//! property runs compiled in this module's unit tests.)

use super::rng::Rng64;

/// Run `prop` over `cases` random cases derived from `seed`. Each case
/// gets an independent RNG (`seed ⊕ case-index`), so a failure message's
/// `case` can be replayed in isolation.
pub fn forall(cases: u32, seed: u64, prop: impl Fn(&mut Rng64)) {
    for case in 0..cases {
        let case_seed = seed ^ ((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng64::seed_from_u64(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "non-string panic".into());
            panic!("property failed at case {case} (replay seed {case_seed:#x}): {msg}");
        }
    }
}

/// A random vector of f32 in `[-scale, scale]` with length in `[1, max_len]`.
pub fn vec_f32(rng: &mut Rng64, max_len: usize, scale: f32) -> Vec<f32> {
    let len = rng.range(1, max_len.max(2));
    (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

/// A random sorted `(col, delta)` sparse update with distinct columns.
pub fn sparse_update(rng: &mut Rng64, width: u32, scale: f32) -> Vec<(u32, f32)> {
    let n = rng.range(1, (width as usize).min(8) + 1);
    let mut cols: Vec<u32> = (0..width).collect();
    rng.shuffle(&mut cols);
    let mut pairs: Vec<(u32, f32)> =
        cols[..n].iter().map(|&c| (c, (rng.f32() * 2.0 - 1.0) * scale)).collect();
    pairs.sort_by_key(|&(c, _)| c);
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(50, 1, |rng| {
            let x = rng.f64();
            assert!(x >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failing_case() {
        forall(50, 2, |rng| {
            // fails eventually (p ≈ 1 − (3/4)^50)
            assert!(rng.f64() < 0.75, "too big");
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall(100, 3, |rng| {
            let v = vec_f32(rng, 16, 2.0);
            assert!(!v.is_empty() && v.len() <= 16);
            assert!(v.iter().all(|x| x.abs() <= 2.0));
            let u = sparse_update(rng, 10, 1.0);
            assert!(!u.is_empty());
            for w in u.windows(2) {
                assert!(w[0].0 < w[1].0, "columns must be distinct & sorted");
            }
        });
    }
}
