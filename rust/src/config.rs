//! Configuration system: topology, network simulation and consistency
//! policy parameters. Build programmatically with [`SystemConfigBuilder`]
//! or load from a simple `key = value` config file
//! ([`SystemConfig::from_file`], see `configs/*.cfg`) — the offline build
//! has no TOML parser, so the file format is a deliberately tiny subset.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::{Error, Result};

/// Which consistency model governs a table, with its tuning knobs.
/// These are exactly the models of paper §2 plus the BSP/SSP baselines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyConfig {
    /// Bulk Synchronous Parallel: barrier every clock; equivalent to
    /// `Ssp { staleness: 0 }` (the paper's BSP Lemma).
    Bsp,
    /// Stale Synchronous Parallel [Ho et al. 2013]: updates ship at
    /// `Clock()`; a reader at clock `c` must see all updates `≤ c-s-1`.
    Ssp {
        /// Maximum clock lead `s` of the fastest over the slowest worker.
        staleness: u32,
    },
    /// Clock-bounded Asynchronous Parallel (paper §2.1): same staleness
    /// guarantee as SSP, but updates propagate eagerly whenever bandwidth
    /// is available rather than only at the clock boundary.
    Cap {
        /// Staleness threshold `s`.
        staleness: u32,
    },
    /// Value-bounded Asynchronous Parallel (paper §2.2): per-parameter
    /// accumulated unsynchronized-update magnitude is kept `< v_thr`.
    Vap {
        /// The value threshold `v_thr`.
        v_thr: f32,
        /// Strong VAP additionally bounds half-synchronized updates by
        /// `max(u, v_thr)` making the replica divergence bound
        /// `2·max(u, v_thr)` independent of the worker count `P`.
        strong: bool,
    },
    /// Clock-Value-bounded Asynchronous Parallel (paper §2.3): the
    /// conjunction of the CAP and VAP guarantees.
    Cvap {
        /// Staleness threshold `s` (CAP side).
        staleness: u32,
        /// Value threshold `v_thr` (VAP side).
        v_thr: f32,
        /// Strong or weak VAP component.
        strong: bool,
    },
    /// Best-effort, YahooLDA-style: no guarantee at all. Included as the
    /// paper's "other extreme" baseline (§1) for the ablation benches.
    BestEffort,
}

impl PolicyConfig {
    /// Staleness bound if the model has one.
    pub fn staleness(&self) -> Option<u32> {
        match *self {
            PolicyConfig::Bsp => Some(0),
            PolicyConfig::Ssp { staleness } | PolicyConfig::Cap { staleness } => Some(staleness),
            PolicyConfig::Cvap { staleness, .. } => Some(staleness),
            PolicyConfig::Vap { .. } | PolicyConfig::BestEffort => None,
        }
    }

    /// Value threshold if the model has one.
    pub fn v_thr(&self) -> Option<f32> {
        match *self {
            PolicyConfig::Vap { v_thr, .. } | PolicyConfig::Cvap { v_thr, .. } => Some(v_thr),
            _ => None,
        }
    }

    /// True for models that propagate updates eagerly (asynchronously)
    /// instead of only at the clock boundary.
    pub fn is_async(&self) -> bool {
        !matches!(self, PolicyConfig::Bsp | PolicyConfig::Ssp { .. })
    }

    /// Validate parameter ranges.
    pub fn validate(&self) -> Result<()> {
        if let Some(v) = self.v_thr() {
            if !(v > 0.0) || !v.is_finite() {
                return Err(Error::Config(format!("v_thr must be finite and > 0, got {v}")));
            }
        }
        Ok(())
    }

    /// Short human name used in metrics/bench output.
    pub fn name(&self) -> String {
        match *self {
            PolicyConfig::Bsp => "bsp".into(),
            PolicyConfig::Ssp { staleness } => format!("ssp(s={staleness})"),
            PolicyConfig::Cap { staleness } => format!("cap(s={staleness})"),
            PolicyConfig::Vap { v_thr, strong } => {
                format!("{}vap(v={v_thr})", if strong { "s" } else { "w" })
            }
            PolicyConfig::Cvap { staleness, v_thr, strong } => {
                format!("{}cvap(s={staleness},v={v_thr})", if strong { "s" } else { "w" })
            }
            PolicyConfig::BestEffort => "best-effort".into(),
        }
    }

    /// Parse a policy spec string: `bsp`, `ssp:S`, `cap:S`, `vap:V`,
    /// `svap:V`, `cvap:S:V`, `scvap:S:V`, `best-effort`.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        let bad = || Error::Config(format!("bad policy spec '{s}'"));
        let p = match parts[0] {
            "bsp" => PolicyConfig::Bsp,
            "best-effort" | "none" => PolicyConfig::BestEffort,
            "ssp" => PolicyConfig::Ssp {
                staleness: parts.get(1).ok_or_else(bad)?.parse().map_err(|_| bad())?,
            },
            "cap" => PolicyConfig::Cap {
                staleness: parts.get(1).ok_or_else(bad)?.parse().map_err(|_| bad())?,
            },
            "vap" | "svap" => PolicyConfig::Vap {
                v_thr: parts.get(1).ok_or_else(bad)?.parse().map_err(|_| bad())?,
                strong: parts[0] == "svap",
            },
            "cvap" | "scvap" => PolicyConfig::Cvap {
                staleness: parts.get(1).ok_or_else(bad)?.parse().map_err(|_| bad())?,
                v_thr: parts.get(2).ok_or_else(bad)?.parse().map_err(|_| bad())?,
                strong: parts[0] == "scvap",
            },
            _ => return Err(bad()),
        };
        p.validate()?;
        Ok(p)
    }
}

/// Simulated-network parameters (substitutes for the paper's 8-node,
/// 40 GbE PRObE cluster — see DESIGN.md §3).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// One-way latency per message in microseconds (0 = direct delivery).
    pub latency_us: u64,
    /// Link bandwidth in bytes/sec (0 = infinite). Messages occupy the
    /// link for `bytes / bandwidth` seconds, creating the congestion the
    /// async models must tolerate.
    pub bandwidth_bps: u64,
    /// Extra latency jitter, uniform in `[0, jitter_us]`.
    pub jitter_us: u64,
    /// RNG seed for jitter reproducibility.
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // Default: ideal network — tests of consistency logic should not
        // depend on timing. Benches override with realistic values.
        NetConfig { latency_us: 0, bandwidth_bps: 0, jitter_us: 0, seed: 0x5EED }
    }
}

impl NetConfig {
    /// A profile resembling the paper's testbed: 40 GbE, ~20 µs RTT.
    pub fn lan_40gbe() -> Self {
        NetConfig { latency_us: 10, bandwidth_bps: 5_000_000_000, jitter_us: 5, seed: 0x5EED }
    }

    /// A slow/congested profile (1 GbE, 200 µs) for the straggler benches.
    pub fn lan_1gbe() -> Self {
        NetConfig { latency_us: 100, bandwidth_bps: 125_000_000, jitter_us: 50, seed: 0x5EED }
    }

    /// Transmission delay of a message of `bytes` under this profile
    /// (latency is added separately by the delivery queue).
    pub fn tx_time(&self, bytes: usize) -> Duration {
        if self.bandwidth_bps == 0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(bytes as f64 / self.bandwidth_bps as f64)
        }
    }
}

/// Straggler injection: slows chosen workers down by a multiplicative
/// factor, the failure mode the paper calls out for best-effort systems
/// ("the system can potentially fail if stragglers present", §1).
#[derive(Debug, Clone, Default)]
pub struct StragglerConfig {
    /// Worker ids to slow down.
    pub workers: Vec<u32>,
    /// Compute-time multiplier (e.g. 10.0 = 10× slower). 1.0 disables.
    pub slowdown: f64,
}

/// Full system configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Number of server shard processes.
    pub num_server_shards: u32,
    /// Number of client (application) processes.
    pub num_client_procs: u32,
    /// Worker threads per client process. Total workers `P =
    /// num_client_procs × threads_per_proc`.
    pub threads_per_proc: u32,
    /// Network simulation profile.
    pub net: NetConfig,
    /// Straggler injection.
    pub stragglers: StragglerConfig,
    /// Background flush interval for the async models, in microseconds:
    /// how often the client egress thread drains the oplog ("whenever the
    /// network bandwidth is available").
    pub flush_interval_us: u64,
    /// Max updates per wire batch (paper §4.2 batches messages).
    pub max_batch_updates: usize,
    /// Deadline for blocking waits (ms); exceeded ⇒ `Error::WaitTimeout`.
    pub wait_timeout_ms: u64,
    /// Blocked readers re-issue their `PullRow` after this long without a
    /// usable reply (doubling each retry). Covers requests that died with
    /// a crashed shard; the pull is idempotent so spurious retries are
    /// harmless. 0 disables retries.
    pub pull_retry_ms: u64,
    /// Coordinator → shard heartbeat period (µs). 0 disables the failure
    /// detector (the default: single-machine tests don't need it).
    pub heartbeat_interval_us: u64,
    /// A shard silent for this long (µs) is declared dead and respawned
    /// from its checkpoint + WAL. Must exceed the heartbeat interval.
    pub heartbeat_deadline_us: u64,
    /// Shards checkpoint after this many WAL records (bounds replay
    /// time). 0 = never checkpoint (WAL-only recovery).
    pub checkpoint_every: u64,
    /// Apply-path worker threads per shard. `1` (default) applies pushes
    /// inline on the shard event loop; `> 1` fans each batch's row updates
    /// across a lane-partitioned worker pool over the striped store. Row
    /// apply order is preserved either way, so results are bit-identical —
    /// the deterministic simulator pins this to 1 regardless.
    pub apply_threads: u32,
    /// Directory holding AOT artifacts (`*.hlo.txt`).
    pub artifacts_dir: PathBuf,
    /// Enable the *legacy* event-trace recorder (costly; used by tests and
    /// the Fig-1 bench). Span capture — the always-on causal tracer — is
    /// independent of this flag and controlled by `trace_ring_slots`.
    pub trace: bool,
    /// Capacity (in spans) of each per-node trace ring. The record path is
    /// lock-free; overflow drops the oldest span and bumps
    /// `trace_spans_dropped_total`.
    pub trace_ring_slots: usize,
    /// Use magnitude-priority ordering when draining the oplog (paper
    /// §4.2); `false` = FIFO. Ablation E6 flips this.
    pub magnitude_priority: bool,
    /// Bind a metrics scrape endpoint here at launch (e.g.
    /// `127.0.0.1:9898`; `:0` picks a free port). `None` = no endpoint.
    pub metrics_listen: Option<String>,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfigBuilder::default().build()
    }
}

impl SystemConfig {
    /// Start building a config.
    pub fn builder() -> SystemConfigBuilder {
        SystemConfigBuilder::default()
    }

    /// Total worker count `P`.
    pub fn num_workers(&self) -> u32 {
        self.num_client_procs * self.threads_per_proc
    }

    /// Load from a `key = value` file (one pair per line; `#` comments).
    /// Recognized keys: `shards`, `procs`, `threads`, `latency_us`,
    /// `bandwidth_bps`, `jitter_us`, `flush_interval_us`,
    /// `max_batch_updates`, `wait_timeout_ms`, `pull_retry_ms`,
    /// `heartbeat_interval_us`, `heartbeat_deadline_us`,
    /// `checkpoint_every`, `apply_threads`, `artifacts_dir`, `trace`,
    /// `magnitude_priority`, `metrics_listen`, `trace_ring_slots`,
    /// `straggler_workers` (comma list), `straggler_slowdown`.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let mut kv = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let mut b = SystemConfig::builder();
        let parse_u32 = |kv: &HashMap<String, String>, k: &str| -> Result<Option<u32>> {
            kv.get(k)
                .map(|v| v.parse().map_err(|_| Error::Config(format!("bad {k}: {v}"))))
                .transpose()
        };
        let parse_u64 = |kv: &HashMap<String, String>, k: &str| -> Result<Option<u64>> {
            kv.get(k)
                .map(|v| v.parse().map_err(|_| Error::Config(format!("bad {k}: {v}"))))
                .transpose()
        };
        if let Some(v) = parse_u32(&kv, "shards")? {
            b = b.num_server_shards(v);
        }
        if let Some(v) = parse_u32(&kv, "procs")? {
            b = b.num_client_procs(v);
        }
        if let Some(v) = parse_u32(&kv, "threads")? {
            b = b.threads_per_proc(v);
        }
        let mut net = NetConfig::default();
        if let Some(v) = parse_u64(&kv, "latency_us")? {
            net.latency_us = v;
        }
        if let Some(v) = parse_u64(&kv, "bandwidth_bps")? {
            net.bandwidth_bps = v;
        }
        if let Some(v) = parse_u64(&kv, "jitter_us")? {
            net.jitter_us = v;
        }
        b = b.net(net);
        if let Some(v) = parse_u64(&kv, "flush_interval_us")? {
            b = b.flush_interval_us(v);
        }
        if let Some(v) = parse_u64(&kv, "max_batch_updates")? {
            b = b.max_batch_updates(v as usize);
        }
        if let Some(v) = parse_u64(&kv, "wait_timeout_ms")? {
            b = b.wait_timeout_ms(v);
        }
        if let Some(v) = parse_u64(&kv, "pull_retry_ms")? {
            b = b.pull_retry_ms(v);
        }
        if let Some(v) = parse_u64(&kv, "heartbeat_interval_us")? {
            b = b.heartbeat_interval_us(v);
        }
        if let Some(v) = parse_u64(&kv, "heartbeat_deadline_us")? {
            b = b.heartbeat_deadline_us(v);
        }
        if let Some(v) = parse_u64(&kv, "checkpoint_every")? {
            b = b.checkpoint_every(v);
        }
        if let Some(v) = parse_u32(&kv, "apply_threads")? {
            b = b.apply_threads(v);
        }
        if let Some(v) = kv.get("artifacts_dir") {
            b = b.artifacts_dir(v.clone());
        }
        if let Some(v) = kv.get("trace") {
            b = b.trace(v == "true" || v == "1");
        }
        if let Some(v) = parse_u64(&kv, "trace_ring_slots")? {
            b = b.trace_ring_slots(v as usize);
        }
        if let Some(v) = kv.get("magnitude_priority") {
            b = b.magnitude_priority(v == "true" || v == "1");
        }
        if let Some(v) = kv.get("metrics_listen") {
            b = b.metrics_listen(v.clone());
        }
        let mut stragglers = StragglerConfig::default();
        if let Some(v) = kv.get("straggler_workers") {
            stragglers.workers = v
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| s.trim().parse().map_err(|_| Error::Config(format!("bad worker id {s}"))))
                .collect::<Result<Vec<u32>>>()?;
        }
        if let Some(v) = kv.get("straggler_slowdown") {
            stragglers.slowdown =
                v.parse().map_err(|_| Error::Config(format!("bad slowdown {v}")))?;
        }
        b = b.stragglers(stragglers);
        let cfg = b.cfg;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check the topology.
    pub fn validate(&self) -> Result<()> {
        if self.num_server_shards == 0 {
            return Err(Error::Config("need ≥ 1 server shard".into()));
        }
        if self.num_client_procs == 0 || self.threads_per_proc == 0 {
            return Err(Error::Config("need ≥ 1 client process and ≥ 1 thread".into()));
        }
        if self.stragglers.slowdown < 0.0 {
            return Err(Error::Config("straggler slowdown must be ≥ 0".into()));
        }
        if self.heartbeat_interval_us > 0
            && self.heartbeat_deadline_us <= self.heartbeat_interval_us
        {
            return Err(Error::Config(
                "heartbeat_deadline_us must exceed heartbeat_interval_us".into(),
            ));
        }
        if self.apply_threads == 0 {
            return Err(Error::Config("apply_threads must be ≥ 1".into()));
        }
        if self.trace_ring_slots == 0 {
            return Err(Error::Config("trace_ring_slots must be ≥ 1".into()));
        }
        Ok(())
    }
}

/// Builder for [`SystemConfig`].
#[derive(Debug, Clone)]
pub struct SystemConfigBuilder {
    cfg: SystemConfig,
}

impl Default for SystemConfigBuilder {
    fn default() -> Self {
        SystemConfigBuilder {
            cfg: SystemConfig {
                num_server_shards: 1,
                num_client_procs: 1,
                threads_per_proc: 1,
                net: NetConfig::default(),
                stragglers: StragglerConfig::default(),
                flush_interval_us: 100,
                max_batch_updates: 4096,
                wait_timeout_ms: 30_000,
                pull_retry_ms: 250,
                heartbeat_interval_us: 0,
                heartbeat_deadline_us: 200_000,
                checkpoint_every: 64,
                apply_threads: 1,
                artifacts_dir: PathBuf::from("artifacts"),
                trace: false,
                trace_ring_slots: crate::trace::DEFAULT_RING_SLOTS,
                magnitude_priority: true,
                metrics_listen: None,
            },
        }
    }
}

impl SystemConfigBuilder {
    /// Set the number of server shards.
    pub fn num_server_shards(mut self, n: u32) -> Self {
        self.cfg.num_server_shards = n;
        self
    }
    /// Set the number of client processes.
    pub fn num_client_procs(mut self, n: u32) -> Self {
        self.cfg.num_client_procs = n;
        self
    }
    /// Set worker threads per client process.
    pub fn threads_per_proc(mut self, n: u32) -> Self {
        self.cfg.threads_per_proc = n;
        self
    }
    /// Set the network profile.
    pub fn net(mut self, net: NetConfig) -> Self {
        self.cfg.net = net;
        self
    }
    /// Inject stragglers.
    pub fn stragglers(mut self, s: StragglerConfig) -> Self {
        self.cfg.stragglers = s;
        self
    }
    /// Set the async flush interval (µs).
    pub fn flush_interval_us(mut self, us: u64) -> Self {
        self.cfg.flush_interval_us = us;
        self
    }
    /// Set the max updates per wire batch.
    pub fn max_batch_updates(mut self, n: usize) -> Self {
        self.cfg.max_batch_updates = n;
        self
    }
    /// Set the blocking-wait deadline (ms).
    pub fn wait_timeout_ms(mut self, ms: u64) -> Self {
        self.cfg.wait_timeout_ms = ms;
        self
    }
    /// Set the blocked-reader pull-retry base interval (ms; 0 = off).
    pub fn pull_retry_ms(mut self, ms: u64) -> Self {
        self.cfg.pull_retry_ms = ms;
        self
    }
    /// Enable the shard failure detector: heartbeat period (µs; 0 = off).
    pub fn heartbeat_interval_us(mut self, us: u64) -> Self {
        self.cfg.heartbeat_interval_us = us;
        self
    }
    /// Set the missed-heartbeat window after which a shard is respawned.
    pub fn heartbeat_deadline_us(mut self, us: u64) -> Self {
        self.cfg.heartbeat_deadline_us = us;
        self
    }
    /// Set the shard checkpoint cadence in WAL records (0 = never).
    pub fn checkpoint_every(mut self, n: u64) -> Self {
        self.cfg.checkpoint_every = n;
        self
    }
    /// Set apply-path worker threads per shard (1 = inline/sequential).
    pub fn apply_threads(mut self, n: u32) -> Self {
        self.cfg.apply_threads = n;
        self
    }
    /// Set the artifacts directory.
    pub fn artifacts_dir(mut self, p: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_dir = p.into();
        self
    }
    /// Enable/disable the event trace.
    pub fn trace(mut self, on: bool) -> Self {
        self.cfg.trace = on;
        self
    }
    /// Per-node span-ring capacity for the causal tracer.
    pub fn trace_ring_slots(mut self, slots: usize) -> Self {
        self.cfg.trace_ring_slots = slots;
        self
    }
    /// Enable/disable magnitude-priority update scheduling.
    pub fn magnitude_priority(mut self, on: bool) -> Self {
        self.cfg.magnitude_priority = on;
        self
    }
    /// Serve the metrics scrape endpoint on this address at launch.
    pub fn metrics_listen(mut self, addr: impl Into<String>) -> Self {
        self.cfg.metrics_listen = Some(addr.into());
        self
    }
    /// Finalize. Panics on invalid topology (programmer error); use
    /// [`SystemConfig::validate`] for user-supplied configs.
    pub fn build(self) -> SystemConfig {
        self.cfg.validate().expect("invalid SystemConfig");
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_are_valid() {
        let c = SystemConfig::default();
        assert_eq!(c.num_workers(), 1);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn policy_accessors() {
        assert_eq!(PolicyConfig::Bsp.staleness(), Some(0));
        assert_eq!(PolicyConfig::Ssp { staleness: 3 }.staleness(), Some(3));
        assert_eq!(PolicyConfig::Vap { v_thr: 8.0, strong: false }.v_thr(), Some(8.0));
        assert!(PolicyConfig::Cap { staleness: 1 }.is_async());
        assert!(!PolicyConfig::Ssp { staleness: 1 }.is_async());
        let c = PolicyConfig::Cvap { staleness: 2, v_thr: 1.0, strong: true };
        assert_eq!(c.staleness(), Some(2));
        assert_eq!(c.v_thr(), Some(1.0));
    }

    #[test]
    fn policy_validation_rejects_bad_vthr() {
        assert!(PolicyConfig::Vap { v_thr: 0.0, strong: false }.validate().is_err());
        assert!(PolicyConfig::Vap { v_thr: f32::NAN, strong: false }.validate().is_err());
        assert!(PolicyConfig::Vap { v_thr: -1.0, strong: true }.validate().is_err());
        assert!(PolicyConfig::Vap { v_thr: 0.5, strong: true }.validate().is_ok());
    }

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(PolicyConfig::parse("bsp").unwrap(), PolicyConfig::Bsp);
        assert_eq!(
            PolicyConfig::parse("ssp:3").unwrap(),
            PolicyConfig::Ssp { staleness: 3 }
        );
        assert_eq!(
            PolicyConfig::parse("svap:2.5").unwrap(),
            PolicyConfig::Vap { v_thr: 2.5, strong: true }
        );
        assert_eq!(
            PolicyConfig::parse("cvap:1:4").unwrap(),
            PolicyConfig::Cvap { staleness: 1, v_thr: 4.0, strong: false }
        );
        assert!(PolicyConfig::parse("vap").is_err());
        assert!(PolicyConfig::parse("wat:1").is_err());
        assert!(PolicyConfig::parse("vap:-1").is_err());
    }

    #[test]
    fn config_file_parsing() {
        let dir = std::env::temp_dir().join(format!("bapps-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.cfg");
        std::fs::write(
            &path,
            "# comment\nshards = 4\nprocs = 2\nthreads = 8\nlatency_us = 10\n\
             straggler_workers = 1,3\nstraggler_slowdown = 5.0\ntrace = true\n",
        )
        .unwrap();
        let cfg = SystemConfig::from_file(&path).unwrap();
        assert_eq!(cfg.num_server_shards, 4);
        assert_eq!(cfg.num_workers(), 16);
        assert_eq!(cfg.net.latency_us, 10);
        assert_eq!(cfg.stragglers.workers, vec![1, 3]);
        assert_eq!(cfg.stragglers.slowdown, 5.0);
        assert!(cfg.trace);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_file_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("bapps-cfg2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cfg");
        std::fs::write(&path, "shards 4\n").unwrap();
        assert!(SystemConfig::from_file(&path).is_err());
        std::fs::write(&path, "shards = many\n").unwrap();
        assert!(SystemConfig::from_file(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tx_time_scales_with_bytes() {
        let n = NetConfig { bandwidth_bps: 1000, ..NetConfig::default() };
        assert_eq!(n.tx_time(500), Duration::from_millis(500));
        let inf = NetConfig::default();
        assert_eq!(inf.tx_time(1 << 30), Duration::ZERO);
    }

    #[test]
    fn names_are_distinct() {
        let names: Vec<String> = [
            PolicyConfig::Bsp,
            PolicyConfig::Ssp { staleness: 1 },
            PolicyConfig::Cap { staleness: 1 },
            PolicyConfig::Vap { v_thr: 1.0, strong: false },
            PolicyConfig::Vap { v_thr: 1.0, strong: true },
            PolicyConfig::Cvap { staleness: 1, v_thr: 1.0, strong: false },
            PolicyConfig::BestEffort,
        ]
        .iter()
        .map(|p| p.name())
        .collect();
        let set: std::collections::HashSet<_> = names.iter().collect();
        assert_eq!(set.len(), names.len());
    }
}
