//! Tables, rows and row updates (paper §4.1).
//!
//! Petuum PS organizes shared parameters as *tables*: a parameter is
//! identified by `(table id, row id, column id)`. Rows are the unit of
//! distribution (hash-partitioned over server shards) and of transmission
//! (pulls and pushes move whole rows / row-deltas). Both **dense** rows
//! (`Vec<f32>`) and **sparse** rows (index→value maps) are supported, and
//! different tables may use different consistency models.

mod row;
mod storage;

pub use row::{RowData, RowUpdate};
pub use storage::TableStore;


use crate::config::PolicyConfig;
use crate::types::ShardId;

/// Identifies one table. The data in one table is homogeneous (f32 here)
/// and one table is bound to one consistency policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TableId(pub u32);

/// Identifies a row within a table. Rows are the unit of distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RowId(pub u64);

/// Dense or sparse row representation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowKind {
    /// Fixed-width `Vec<f32>` row; column ids are direct indices.
    Dense,
    /// Map from column id to value; absent columns read as 0.0.
    Sparse,
}

/// Everything needed to create a table on every shard and client.
#[derive(Debug, Clone)]
pub struct TableDesc {
    /// Table id, chosen by the application; must be unique.
    pub id: TableId,
    /// Number of rows. Row ids must be `< num_rows`.
    pub num_rows: u64,
    /// Width of each row (dense: exact; sparse: column-id upper bound).
    pub row_width: u32,
    /// Dense or sparse rows.
    pub row_kind: RowKind,
    /// The consistency model governing this table. Different tables may use
    /// different models (paper §4.1).
    pub policy: PolicyConfig,
}

impl TableDesc {
    /// The shard that owns `row`, by hash partitioning. Row is the unit of
    /// data distribution (paper §4.1); we use a multiplicative hash so
    /// consecutive row ids spread across shards (LDA touches word ids in
    /// corpus order — modulo would be fine, but hashing also decorrelates
    /// hot vocabulary prefixes).
    pub fn shard_of(&self, row: RowId, num_shards: u32) -> ShardId {
        // SplitMix64 finalizer — cheap, well-distributed, stable across runs.
        let mut z = row.0 ^ (self.id.0 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        ShardId((z % num_shards as u64) as u32)
    }

    /// Validate the descriptor (row counts, widths) before creation.
    pub fn validate(&self) -> crate::error::Result<()> {
        if self.num_rows == 0 {
            return Err(crate::error::Error::Config(format!(
                "table {:?}: num_rows must be > 0",
                self.id
            )));
        }
        if self.row_width == 0 {
            return Err(crate::error::Error::Config(format!(
                "table {:?}: row_width must be > 0",
                self.id
            )));
        }
        self.policy.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn desc(id: u32) -> TableDesc {
        TableDesc {
            id: TableId(id),
            num_rows: 1000,
            row_width: 8,
            row_kind: RowKind::Dense,
            policy: PolicyConfig::Ssp { staleness: 1 },
        }
    }

    #[test]
    fn shard_partitioning_is_stable_and_in_range() {
        let d = desc(1);
        for r in 0..1000u64 {
            let s1 = d.shard_of(RowId(r), 4);
            let s2 = d.shard_of(RowId(r), 4);
            assert_eq!(s1, s2);
            assert!(s1.0 < 4);
        }
    }

    #[test]
    fn shard_partitioning_is_roughly_balanced() {
        let d = desc(2);
        let mut counts = [0usize; 8];
        for r in 0..8000u64 {
            counts[d.shard_of(RowId(r), 8).0 as usize] += 1;
        }
        for &c in &counts {
            // expect ~1000 per shard; allow 25% imbalance
            assert!((750..=1250).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn different_tables_hash_rows_differently() {
        let d1 = desc(1);
        let d2 = desc(2);
        let differs = (0..100u64)
            .any(|r| d1.shard_of(RowId(r), 16) != d2.shard_of(RowId(r), 16));
        assert!(differs);
    }

    #[test]
    fn validate_rejects_degenerate_tables() {
        let mut d = desc(0);
        d.num_rows = 0;
        assert!(d.validate().is_err());
        let mut d = desc(0);
        d.row_width = 0;
        assert!(d.validate().is_err());
        assert!(desc(0).validate().is_ok());
    }
}
