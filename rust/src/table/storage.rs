//! In-memory row storage used by server shards and the process cache.
//!
//! A `TableStore` maps `RowId → (RowData, row clock)`. The row clock is the
//! metadata the clock-bounded models key off: on the **server** it is the
//! min process clock at the time the row version was formed; in the
//! **process cache** it records how fresh the cached copy is.
//!
//! ## Concurrency
//!
//! The store is **stripe-locked**: rows hash into [`NUM_STRIPES`]
//! independent `RwLock<HashMap>` stripes, so writers on different stripes
//! never contend and readers never block writers on other stripes. All
//! methods take `&self`; share the store across threads with `Arc`.
//!
//! Row values are `Arc<RowData>` **copy-on-write**: reading a row
//! ([`TableStore::get`]) hands out a cheap `Arc` clone instead of
//! deep-copying the vector, which is what lets pull replies and checkpoint
//! images borrow row data without cloning it. Writers mutate through
//! `Arc::make_mut`, which copies only when a reader still holds the old
//! version — the common uncontended case mutates in place.
//!
//! Byte accounting ([`TableStore::approx_bytes`]) is a running atomic
//! counter maintained on `apply`/`install`/`evict`, so cache-accounting
//! callers pay O(1) instead of a full scan.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock, RwLockWriteGuard, TryLockError};

use crate::table::{RowData, RowId, RowKind, RowUpdate};
use crate::types::Clock;

/// Lock stripes per store (power of two so the stripe index is a mask).
pub const NUM_STRIPES: usize = 16;

/// Fixed per-row bookkeeping overhead charged by the byte accounting
/// (id + clock + map slot), matching the historical `approx_bytes` formula.
const ROW_OVERHEAD: usize = 16;

/// One cached/stored row with its freshness clock.
#[derive(Debug, Clone)]
pub struct StoredRow {
    /// Current value (copy-on-write; cloning a `StoredRow` is O(1)).
    pub data: Arc<RowData>,
    /// Freshness: all updates with timestamp `≤ clock` from every worker
    /// are reflected in `data` (clock-bounded models), best-effort newer
    /// updates may also be included (paper eq. (1) "best-effort in-window").
    pub clock: Clock,
}

/// Storage for the rows of one table on one node. Rows materialize lazily
/// (zeros) on first touch so creating a billion-row sparse table is free.
#[derive(Debug)]
pub struct TableStore {
    kind: RowKind,
    width: u32,
    stripes: Box<[RwLock<HashMap<RowId, StoredRow>>]>,
    /// Running `approx_bytes` total (O(1) reads for cache accounting).
    bytes: AtomicUsize,
    /// Materialized row count.
    rows: AtomicUsize,
    /// Stripe write-lock acquisitions that found the lock held (contention
    /// diagnostic for the parallel apply path).
    contended: AtomicU64,
}

/// SplitMix64 finalizer — decorrelates sequential row ids across stripes
/// (same mixer family as `TableDesc::shard_of`, different constants path
/// so stripe choice is independent of shard choice).
fn mix(row: u64) -> u64 {
    let mut z = row.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl TableStore {
    /// New empty store for rows of the given shape.
    pub fn new(kind: RowKind, width: u32) -> Self {
        let stripes: Vec<RwLock<HashMap<RowId, StoredRow>>> =
            (0..NUM_STRIPES).map(|_| RwLock::new(HashMap::new())).collect();
        TableStore {
            kind,
            width,
            stripes: stripes.into_boxed_slice(),
            bytes: AtomicUsize::new(0),
            rows: AtomicUsize::new(0),
            contended: AtomicU64::new(0),
        }
    }

    /// Row width (dense width / sparse column bound).
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Row representation kind.
    pub fn kind(&self) -> RowKind {
        self.kind
    }

    /// Stripe index of a row (stable for the store's lifetime; the apply
    /// pool partitions batch updates by this).
    pub fn stripe_of(&self, row: RowId) -> usize {
        (mix(row.0) as usize) & (NUM_STRIPES - 1)
    }

    /// Number of lock stripes.
    pub fn num_stripes(&self) -> usize {
        NUM_STRIPES
    }

    /// Write-lock one stripe, counting contention when the lock was held.
    fn write_stripe(&self, i: usize) -> RwLockWriteGuard<'_, HashMap<RowId, StoredRow>> {
        match self.stripes[i].try_write() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.contended.fetch_add(1, Ordering::Relaxed);
                self.stripes[i].write().unwrap()
            }
            Err(TryLockError::Poisoned(_)) => self.stripes[i].write().unwrap(),
        }
    }

    fn adjust_bytes(&self, before: usize, after: usize) {
        if after >= before {
            self.bytes.fetch_add(after - before, Ordering::Relaxed);
        } else {
            self.bytes.fetch_sub(before - after, Ordering::Relaxed);
        }
    }

    /// Read a row; `None` if it has never been touched (semantically a
    /// zero row at clock 0). Returns an owned `StoredRow` — an O(1) `Arc`
    /// clone of the value, never a deep copy.
    pub fn get(&self, row: RowId) -> Option<StoredRow> {
        self.stripes[self.stripe_of(row)].read().unwrap().get(&row).cloned()
    }

    /// Apply an update delta to a row (materializing it if needed).
    pub fn apply(&self, row: RowId, update: &RowUpdate) {
        let mut g = self.write_stripe(self.stripe_of(row));
        match g.entry(row) {
            Entry::Occupied(mut e) => {
                let sr = e.get_mut();
                let before = sr.data.wire_bytes();
                Arc::make_mut(&mut sr.data).apply(update);
                let after = sr.data.wire_bytes();
                drop(g);
                self.adjust_bytes(before, after);
            }
            Entry::Vacant(e) => {
                let mut data = RowData::zeros(self.kind, self.width);
                data.apply(update);
                let after = data.wire_bytes();
                e.insert(StoredRow { data: Arc::new(data), clock: 0 });
                drop(g);
                self.rows.fetch_add(1, Ordering::Relaxed);
                self.bytes.fetch_add(after + ROW_OVERHEAD, Ordering::Relaxed);
            }
        }
    }

    /// Apply the subset of `updates` whose stripe maps to `lane` (i.e.
    /// `stripe_of(row) % num_lanes == lane`), in slice order. The apply
    /// pool gives each worker thread one lane, so every stripe is only
    /// ever written by one worker per batch and the per-row apply order
    /// equals the batch order — float applies stay deterministic.
    pub fn apply_lane(&self, updates: &[(RowId, RowUpdate)], lane: usize, num_lanes: usize) {
        for (row, u) in updates {
            if self.stripe_of(*row) % num_lanes == lane {
                self.apply(*row, u);
            }
        }
    }

    /// Replace a row wholesale (pull replies / server pushes of full rows).
    /// Keeps the *maximum* of the stored and incoming clock: a full-row
    /// install can never make the local copy less fresh.
    pub fn install(&self, row: RowId, data: Arc<RowData>, clock: Clock) {
        let mut g = self.write_stripe(self.stripe_of(row));
        match g.entry(row) {
            Entry::Occupied(mut e) => {
                let sr = e.get_mut();
                if clock >= sr.clock {
                    let before = sr.data.wire_bytes();
                    let after = data.wire_bytes();
                    sr.data = data;
                    sr.clock = clock;
                    drop(g);
                    self.adjust_bytes(before, after);
                }
            }
            Entry::Vacant(e) => {
                let after = data.wire_bytes();
                e.insert(StoredRow { data, clock });
                drop(g);
                self.rows.fetch_add(1, Ordering::Relaxed);
                self.bytes.fetch_add(after + ROW_OVERHEAD, Ordering::Relaxed);
            }
        }
    }

    /// Advance a row's freshness clock without changing the data (used when
    /// the server learns the global min advanced and its stored value is
    /// thereby known to cover all updates ≤ new min). Materializes a zero
    /// row if absent.
    pub fn bump_clock(&self, row: RowId, clock: Clock) {
        let mut g = self.write_stripe(self.stripe_of(row));
        match g.entry(row) {
            Entry::Occupied(mut e) => {
                let sr = e.get_mut();
                if clock > sr.clock {
                    sr.clock = clock;
                }
            }
            Entry::Vacant(e) => {
                let data = RowData::zeros(self.kind, self.width);
                let after = data.wire_bytes();
                e.insert(StoredRow { data: Arc::new(data), clock });
                drop(g);
                self.rows.fetch_add(1, Ordering::Relaxed);
                self.bytes.fetch_add(after + ROW_OVERHEAD, Ordering::Relaxed);
            }
        }
    }

    /// Advance every materialized row's clock (server-side on min-clock
    /// advance: the stored values now reflect every update ≤ `clock`).
    pub fn bump_all_clocks(&self, clock: Clock) {
        for s in self.stripes.iter() {
            for sr in s.write().unwrap().values_mut() {
                if clock > sr.clock {
                    sr.clock = clock;
                }
            }
        }
    }

    /// Number of materialized rows.
    pub fn len(&self) -> usize {
        self.rows.load(Ordering::Relaxed)
    }

    /// True when no row has been materialized.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Consistent-enough copy of all materialized rows, sorted by row id
    /// (checkpoint imaging, tests). Values are O(1) `Arc` clones. Each
    /// stripe is snapshotted atomically; the caller serializes against
    /// concurrent writers if cross-stripe atomicity matters (the shard
    /// event loop checkpoints only between batches, so it does).
    pub fn snapshot_rows(&self) -> Vec<(RowId, StoredRow)> {
        let mut out: Vec<(RowId, StoredRow)> = Vec::with_capacity(self.len());
        for s in self.stripes.iter() {
            let g = s.read().unwrap();
            out.extend(g.iter().map(|(k, v)| (*k, v.clone())));
        }
        out.sort_unstable_by_key(|(id, _)| id.0);
        out
    }

    /// Drop a cached row (cache eviction).
    pub fn evict(&self, row: RowId) -> bool {
        let mut g = self.write_stripe(self.stripe_of(row));
        match g.remove(&row) {
            Some(sr) => {
                let freed = sr.data.wire_bytes() + ROW_OVERHEAD;
                drop(g);
                self.rows.fetch_sub(1, Ordering::Relaxed);
                self.bytes.fetch_sub(freed, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Total approximate bytes held (cache accounting). O(1): maintained
    /// as a running counter on `apply`/`install`/`evict`.
    pub fn approx_bytes(&self) -> usize {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Cumulative stripe write-lock contention events (diagnostics for the
    /// parallel apply path; monotone).
    pub fn contended(&self) -> u64 {
        self.contended.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_materialization() {
        let s = TableStore::new(RowKind::Dense, 4);
        assert!(s.get(RowId(3)).is_none());
        s.apply(RowId(3), &RowUpdate::single(1, 2.0));
        assert_eq!(s.get(RowId(3)).unwrap().data.get(1), Some(2.0));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn install_respects_clock_ordering() {
        let s = TableStore::new(RowKind::Dense, 2);
        s.install(RowId(0), Arc::new(RowData::Dense(vec![1.0, 1.0])), 5);
        // stale install ignored
        s.install(RowId(0), Arc::new(RowData::Dense(vec![9.0, 9.0])), 3);
        assert_eq!(s.get(RowId(0)).unwrap().data.get(0), Some(1.0));
        assert_eq!(s.get(RowId(0)).unwrap().clock, 5);
        // fresher install wins
        s.install(RowId(0), Arc::new(RowData::Dense(vec![2.0, 2.0])), 7);
        assert_eq!(s.get(RowId(0)).unwrap().clock, 7);
        assert_eq!(s.get(RowId(0)).unwrap().data.get(0), Some(2.0));
    }

    #[test]
    fn bump_clock_never_regresses() {
        let s = TableStore::new(RowKind::Sparse, 100);
        s.apply(RowId(1), &RowUpdate::single(0, 1.0));
        s.bump_clock(RowId(1), 4);
        s.bump_clock(RowId(1), 2);
        assert_eq!(s.get(RowId(1)).unwrap().clock, 4);
    }

    #[test]
    fn bump_all_clocks_touches_only_materialized() {
        let s = TableStore::new(RowKind::Dense, 2);
        s.apply(RowId(0), &RowUpdate::single(0, 1.0));
        s.apply(RowId(5), &RowUpdate::single(1, 1.0));
        s.bump_all_clocks(9);
        assert_eq!(s.get(RowId(0)).unwrap().clock, 9);
        assert_eq!(s.get(RowId(5)).unwrap().clock, 9);
        assert!(s.get(RowId(1)).is_none());
    }

    #[test]
    fn evict_and_bytes() {
        let s = TableStore::new(RowKind::Dense, 8);
        s.apply(RowId(0), &RowUpdate::single(0, 1.0));
        assert!(s.approx_bytes() >= 32);
        assert!(s.evict(RowId(0)));
        assert!(!s.evict(RowId(0)));
        assert!(s.is_empty());
        assert_eq!(s.approx_bytes(), 0);
    }

    /// The running byte counter must equal a from-scratch scan after any
    /// mix of apply / install / evict — including sparse rows whose size
    /// shrinks when entries cancel to zero.
    #[test]
    fn approx_bytes_matches_full_scan() {
        let s = TableStore::new(RowKind::Sparse, 1000);
        for i in 0..50u64 {
            s.apply(RowId(i % 7), &RowUpdate::single((i % 5) as u32, 1.0));
        }
        // cancel some entries back to zero (sparse rows drop them)
        for i in 0..20u64 {
            s.apply(RowId(i % 7), &RowUpdate::single((i % 5) as u32, -1.0));
        }
        s.install(RowId(100), Arc::new(RowData::Sparse([(3, 2.0)].into_iter().collect())), 4);
        s.evict(RowId(0));
        let scan: usize =
            s.snapshot_rows().iter().map(|(_, sr)| sr.data.wire_bytes() + 16).sum();
        assert_eq!(s.approx_bytes(), scan);
    }

    #[test]
    fn snapshot_rows_sorted_and_cheap() {
        let s = TableStore::new(RowKind::Dense, 2);
        for i in [9u64, 3, 7, 1] {
            s.apply(RowId(i), &RowUpdate::single(0, i as f32));
        }
        let snap = s.snapshot_rows();
        let ids: Vec<u64> = snap.iter().map(|(id, _)| id.0).collect();
        assert_eq!(ids, vec![1, 3, 7, 9]);
        // the snapshot shares data with the store (CoW, not deep copy)
        let live = s.get(RowId(3)).unwrap();
        assert!(Arc::ptr_eq(&live.data, &snap[1].1.data));
    }

    /// Copy-on-write: a reader holding a row's `Arc` keeps the old value
    /// while a concurrent apply produces a new version.
    #[test]
    fn cow_preserves_reader_snapshot() {
        let s = TableStore::new(RowKind::Dense, 2);
        s.apply(RowId(0), &RowUpdate::single(0, 1.0));
        let before = s.get(RowId(0)).unwrap();
        s.apply(RowId(0), &RowUpdate::single(0, 1.0));
        assert_eq!(before.data.get(0), Some(1.0), "reader's snapshot must not move");
        assert_eq!(s.get(RowId(0)).unwrap().data.get(0), Some(2.0));
    }

    /// apply_lane over all lanes covers exactly the full update list, with
    /// per-row order preserved, so lane-parallel apply equals sequential.
    #[test]
    fn apply_lane_partitions_cover_sequential() {
        let updates: Vec<(RowId, RowUpdate)> =
            (0..200u64).map(|i| (RowId(i % 17), RowUpdate::single(0, 0.5 + i as f32))).collect();
        let seq = TableStore::new(RowKind::Dense, 4);
        for (row, u) in &updates {
            seq.apply(*row, u);
        }
        let laned = TableStore::new(RowKind::Dense, 4);
        for lane in 0..3 {
            laned.apply_lane(&updates, lane, 3);
        }
        for i in 0..17u64 {
            assert_eq!(
                seq.get(RowId(i)).unwrap().data.get(0),
                laned.get(RowId(i)).unwrap().data.get(0),
                "row {i} diverged"
            );
        }
        assert_eq!(seq.approx_bytes(), laned.approx_bytes());
    }
}
