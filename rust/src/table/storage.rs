//! In-memory row storage used by server shards and the process cache.
//!
//! A `TableStore` maps `RowId → (RowData, row clock)`. The row clock is the
//! metadata the clock-bounded models key off: on the **server** it is the
//! min process clock at the time the row version was formed; in the
//! **process cache** it records how fresh the cached copy is.

use std::collections::HashMap;

use crate::table::{RowData, RowId, RowKind, RowUpdate};
use crate::types::Clock;

/// One cached/stored row with its freshness clock.
#[derive(Debug, Clone)]
pub struct StoredRow {
    /// Current value.
    pub data: RowData,
    /// Freshness: all updates with timestamp `≤ clock` from every worker
    /// are reflected in `data` (clock-bounded models), best-effort newer
    /// updates may also be included (paper eq. (1) "best-effort in-window").
    pub clock: Clock,
}

/// Storage for the rows of one table on one node. Rows materialize lazily
/// (zeros) on first touch so creating a billion-row sparse table is free.
#[derive(Debug)]
pub struct TableStore {
    kind: RowKind,
    width: u32,
    rows: HashMap<RowId, StoredRow>,
}

impl TableStore {
    /// New empty store for rows of the given shape.
    pub fn new(kind: RowKind, width: u32) -> Self {
        TableStore { kind, width, rows: HashMap::new() }
    }

    /// Row width (dense width / sparse column bound).
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Row representation kind.
    pub fn kind(&self) -> RowKind {
        self.kind
    }

    /// Read-only access; `None` if the row has never been touched
    /// (semantically a zero row at clock 0).
    pub fn get(&self, row: RowId) -> Option<&StoredRow> {
        self.rows.get(&row)
    }

    /// Mutable access, materializing a zero row on first touch.
    pub fn get_or_init(&mut self, row: RowId) -> &mut StoredRow {
        let (kind, width) = (self.kind, self.width);
        self.rows
            .entry(row)
            .or_insert_with(|| StoredRow { data: RowData::zeros(kind, width), clock: 0 })
    }

    /// Apply an update delta to a row (materializing it if needed).
    pub fn apply(&mut self, row: RowId, update: &RowUpdate) {
        self.get_or_init(row).data.apply(update);
    }

    /// Replace a row wholesale (pull replies / server pushes of full rows).
    /// Keeps the *maximum* of the stored and incoming clock: a full-row
    /// install can never make the local copy less fresh.
    pub fn install(&mut self, row: RowId, data: RowData, clock: Clock) {
        match self.rows.get_mut(&row) {
            Some(sr) => {
                if clock >= sr.clock {
                    sr.data = data;
                    sr.clock = clock;
                }
            }
            None => {
                self.rows.insert(row, StoredRow { data, clock });
            }
        }
    }

    /// Advance a row's freshness clock without changing the data (used when
    /// the server learns the global min advanced and its stored value is
    /// thereby known to cover all updates ≤ new min).
    pub fn bump_clock(&mut self, row: RowId, clock: Clock) {
        let sr = self.get_or_init(row);
        if clock > sr.clock {
            sr.clock = clock;
        }
    }

    /// Advance every materialized row's clock (server-side on min-clock
    /// advance: the stored values now reflect every update ≤ `clock`).
    pub fn bump_all_clocks(&mut self, clock: Clock) {
        for sr in self.rows.values_mut() {
            if clock > sr.clock {
                sr.clock = clock;
            }
        }
    }

    /// Number of materialized rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no row has been materialized.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Iterate materialized rows.
    pub fn iter(&self) -> impl Iterator<Item = (RowId, &StoredRow)> + '_ {
        self.rows.iter().map(|(k, v)| (*k, v))
    }

    /// Drop a cached row (cache eviction).
    pub fn evict(&mut self, row: RowId) -> bool {
        self.rows.remove(&row).is_some()
    }

    /// Total approximate bytes held (cache accounting).
    pub fn approx_bytes(&self) -> usize {
        self.rows.values().map(|r| r.data.wire_bytes() + 16).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_materialization() {
        let mut s = TableStore::new(RowKind::Dense, 4);
        assert!(s.get(RowId(3)).is_none());
        s.apply(RowId(3), &RowUpdate::single(1, 2.0));
        assert_eq!(s.get(RowId(3)).unwrap().data.get(1), Some(2.0));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn install_respects_clock_ordering() {
        let mut s = TableStore::new(RowKind::Dense, 2);
        s.install(RowId(0), RowData::Dense(vec![1.0, 1.0]), 5);
        // stale install ignored
        s.install(RowId(0), RowData::Dense(vec![9.0, 9.0]), 3);
        assert_eq!(s.get(RowId(0)).unwrap().data.get(0), Some(1.0));
        assert_eq!(s.get(RowId(0)).unwrap().clock, 5);
        // fresher install wins
        s.install(RowId(0), RowData::Dense(vec![2.0, 2.0]), 7);
        assert_eq!(s.get(RowId(0)).unwrap().clock, 7);
        assert_eq!(s.get(RowId(0)).unwrap().data.get(0), Some(2.0));
    }

    #[test]
    fn bump_clock_never_regresses() {
        let mut s = TableStore::new(RowKind::Sparse, 100);
        s.apply(RowId(1), &RowUpdate::single(0, 1.0));
        s.bump_clock(RowId(1), 4);
        s.bump_clock(RowId(1), 2);
        assert_eq!(s.get(RowId(1)).unwrap().clock, 4);
    }

    #[test]
    fn bump_all_clocks_touches_only_materialized() {
        let mut s = TableStore::new(RowKind::Dense, 2);
        s.apply(RowId(0), &RowUpdate::single(0, 1.0));
        s.apply(RowId(5), &RowUpdate::single(1, 1.0));
        s.bump_all_clocks(9);
        assert_eq!(s.get(RowId(0)).unwrap().clock, 9);
        assert_eq!(s.get(RowId(5)).unwrap().clock, 9);
        assert!(s.get(RowId(1)).is_none());
    }

    #[test]
    fn evict_and_bytes() {
        let mut s = TableStore::new(RowKind::Dense, 8);
        s.apply(RowId(0), &RowUpdate::single(0, 1.0));
        assert!(s.approx_bytes() >= 32);
        assert!(s.evict(RowId(0)));
        assert!(!s.evict(RowId(0)));
        assert!(s.is_empty());
    }
}
