//! Row values and row updates.
//!
//! Updates are deltas applied with the abelian `+` operator (paper §2:
//! `θ ← θ + δ`, associative and commutative), so updates from different
//! workers can be aggregated by summation in any order — the property every
//! consistency model here leans on.

use std::collections::BTreeMap;

/// The materialized value of one row: dense vector or sparse map.
///
/// Sparse rows read missing columns as `0.0` and drop entries that return
/// to exactly `0.0` after an update (LDA count rows shrink when topics die).
#[derive(Debug, Clone, PartialEq)]
pub enum RowData {
    /// Fixed-width dense row.
    Dense(Vec<f32>),
    /// Sparse row: sorted column→value map.
    Sparse(BTreeMap<u32, f32>),
}

impl RowData {
    /// A zeroed row of the given kind/width.
    pub fn zeros(kind: super::RowKind, width: u32) -> Self {
        match kind {
            super::RowKind::Dense => RowData::Dense(vec![0.0; width as usize]),
            super::RowKind::Sparse => RowData::Sparse(BTreeMap::new()),
        }
    }

    /// Read one column (sparse absent ⇒ 0.0; dense out-of-range ⇒ None).
    pub fn get(&self, col: u32) -> Option<f32> {
        match self {
            RowData::Dense(v) => v.get(col as usize).copied(),
            RowData::Sparse(m) => Some(m.get(&col).copied().unwrap_or(0.0)),
        }
    }

    /// Materialize as a dense vector of `width` (sparse fills zeros).
    pub fn to_dense(&self, width: u32) -> Vec<f32> {
        match self {
            RowData::Dense(v) => v.clone(),
            RowData::Sparse(m) => {
                let mut out = vec![0.0; width as usize];
                for (&c, &v) in m {
                    if (c as usize) < out.len() {
                        out[c as usize] = v;
                    }
                }
                out
            }
        }
    }

    /// Apply an update delta in place.
    pub fn apply(&mut self, update: &RowUpdate) {
        match (self, update) {
            (RowData::Dense(v), RowUpdate::Dense(d)) => {
                for (x, dx) in v.iter_mut().zip(d.iter()) {
                    *x += dx;
                }
            }
            (RowData::Dense(v), RowUpdate::Sparse(pairs)) => {
                for &(c, dv) in pairs {
                    if let Some(x) = v.get_mut(c as usize) {
                        *x += dv;
                    }
                }
            }
            (RowData::Sparse(m), RowUpdate::Sparse(pairs)) => {
                for &(c, dv) in pairs {
                    let e = m.entry(c).or_insert(0.0);
                    *e += dv;
                    if *e == 0.0 {
                        m.remove(&c);
                    }
                }
            }
            (RowData::Sparse(m), RowUpdate::Dense(d)) => {
                for (c, &dv) in d.iter().enumerate() {
                    if dv != 0.0 {
                        let e = m.entry(c as u32).or_insert(0.0);
                        *e += dv;
                        if *e == 0.0 {
                            m.remove(&(c as u32));
                        }
                    }
                }
            }
        }
    }

    /// Number of explicitly stored values.
    pub fn nnz(&self) -> usize {
        match self {
            RowData::Dense(v) => v.len(),
            RowData::Sparse(m) => m.len(),
        }
    }

    /// Approximate serialized size in bytes (for the bandwidth simulator).
    pub fn wire_bytes(&self) -> usize {
        match self {
            RowData::Dense(v) => 4 * v.len(),
            RowData::Sparse(m) => 8 * m.len(),
        }
    }
}

/// A delta to one row: dense vector of per-column deltas, or sparse
/// `(col, delta)` pairs. Updates form the oplog entries, the wire batches
/// and the VAP magnitude-accounting unit.
#[derive(Debug, Clone, PartialEq)]
pub enum RowUpdate {
    /// Per-column deltas, aligned with a dense row.
    Dense(Vec<f32>),
    /// Sorted-by-construction `(col, delta)` pairs.
    Sparse(Vec<(u32, f32)>),
}

impl RowUpdate {
    /// A single-column delta.
    pub fn single(col: u32, delta: f32) -> Self {
        RowUpdate::Sparse(vec![(col, delta)])
    }

    /// Merge another update into this one (summing overlapping columns).
    /// Associativity + commutativity of `+` make any merge order valid.
    pub fn merge(&mut self, other: &RowUpdate) {
        match (&mut *self, other) {
            (RowUpdate::Dense(a), RowUpdate::Dense(b)) => {
                if a.len() < b.len() {
                    a.resize(b.len(), 0.0);
                }
                for (x, y) in a.iter_mut().zip(b.iter()) {
                    *x += y;
                }
            }
            (RowUpdate::Dense(a), RowUpdate::Sparse(pairs)) => {
                for &(c, dv) in pairs {
                    if a.len() <= c as usize {
                        a.resize(c as usize + 1, 0.0);
                    }
                    a[c as usize] += dv;
                }
            }
            (RowUpdate::Sparse(pairs), other) => {
                let mut m: BTreeMap<u32, f32> = pairs.iter().copied().collect();
                match other {
                    RowUpdate::Dense(b) => {
                        for (c, &dv) in b.iter().enumerate() {
                            if dv != 0.0 {
                                *m.entry(c as u32).or_insert(0.0) += dv;
                            }
                        }
                    }
                    RowUpdate::Sparse(bp) => {
                        for &(c, dv) in bp {
                            *m.entry(c).or_insert(0.0) += dv;
                        }
                    }
                }
                *self = RowUpdate::Sparse(m.into_iter().collect());
            }
        }
    }

    /// L∞ magnitude of the update — the paper's `|u|` used both for the
    /// VAP value bound and for magnitude-priority scheduling (§4.2: "we by
    /// default prioritize updates with larger magnitude").
    pub fn magnitude(&self) -> f32 {
        match self {
            RowUpdate::Dense(v) => v.iter().fold(0.0f32, |m, x| m.max(x.abs())),
            RowUpdate::Sparse(p) => p.iter().fold(0.0f32, |m, (_, x)| m.max(x.abs())),
        }
    }

    /// L1 mass of the update (used for per-parameter VAP accounting when
    /// aggregating across columns).
    pub fn l1(&self) -> f32 {
        match self {
            RowUpdate::Dense(v) => v.iter().map(|x| x.abs()).sum(),
            RowUpdate::Sparse(p) => p.iter().map(|(_, x)| x.abs()).sum(),
        }
    }

    /// Per-column iterator of `(col, delta)` with zero deltas skipped.
    pub fn iter_nonzero(&self) -> Box<dyn Iterator<Item = (u32, f32)> + '_> {
        match self {
            RowUpdate::Dense(v) => Box::new(
                v.iter().enumerate().filter(|(_, &x)| x != 0.0).map(|(c, &x)| (c as u32, x)),
            ),
            RowUpdate::Sparse(p) => Box::new(p.iter().copied().filter(|&(_, x)| x != 0.0)),
        }
    }

    /// Approximate serialized size in bytes (bandwidth simulation).
    pub fn wire_bytes(&self) -> usize {
        match self {
            RowUpdate::Dense(v) => 4 * v.len(),
            RowUpdate::Sparse(p) => 8 * p.len(),
        }
    }

    /// True when every delta is exactly zero.
    pub fn is_zero(&self) -> bool {
        match self {
            RowUpdate::Dense(v) => v.iter().all(|&x| x == 0.0),
            RowUpdate::Sparse(p) => p.iter().all(|&(_, x)| x == 0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::RowKind;

    #[test]
    fn dense_apply_and_get() {
        let mut r = RowData::zeros(RowKind::Dense, 4);
        r.apply(&RowUpdate::Dense(vec![1.0, 2.0, 3.0, 4.0]));
        r.apply(&RowUpdate::single(2, -3.0));
        assert_eq!(r.get(0), Some(1.0));
        assert_eq!(r.get(2), Some(0.0));
        assert_eq!(r.get(4), None);
        assert_eq!(r.to_dense(4), vec![1.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn sparse_apply_drops_zeros() {
        let mut r = RowData::zeros(RowKind::Sparse, 100);
        r.apply(&RowUpdate::single(7, 2.0));
        r.apply(&RowUpdate::single(9, 1.0));
        assert_eq!(r.nnz(), 2);
        r.apply(&RowUpdate::single(7, -2.0));
        assert_eq!(r.nnz(), 1, "zeroed entry must be dropped");
        assert_eq!(r.get(7), Some(0.0));
        assert_eq!(r.get(9), Some(1.0));
    }

    #[test]
    fn sparse_row_accepts_dense_update() {
        let mut r = RowData::zeros(RowKind::Sparse, 4);
        r.apply(&RowUpdate::Dense(vec![0.0, 5.0, 0.0, -1.0]));
        assert_eq!(r.nnz(), 2);
        assert_eq!(r.to_dense(4), vec![0.0, 5.0, 0.0, -1.0]);
    }

    #[test]
    fn merge_is_commutative_on_result() {
        let a0 = RowUpdate::Sparse(vec![(1, 1.0), (3, 2.0)]);
        let b0 = RowUpdate::Dense(vec![0.5, -1.0, 0.0, 1.0]);
        let mut ab = a0.clone();
        ab.merge(&b0);
        let mut ba = b0.clone();
        ba.merge(&a0);
        // representations differ (sparse vs dense) but the effect on a row
        // must be identical.
        let mut r1 = RowData::zeros(RowKind::Dense, 4);
        let mut r2 = RowData::zeros(RowKind::Dense, 4);
        r1.apply(&ab);
        r2.apply(&ba);
        assert_eq!(r1.to_dense(4), r2.to_dense(4));
    }

    #[test]
    fn magnitude_and_l1() {
        let u = RowUpdate::Sparse(vec![(0, -3.0), (5, 2.0)]);
        assert_eq!(u.magnitude(), 3.0);
        assert_eq!(u.l1(), 5.0);
        let u = RowUpdate::Dense(vec![0.0, 0.0]);
        assert_eq!(u.magnitude(), 0.0);
        assert!(u.is_zero());
    }

    #[test]
    fn iter_nonzero_skips_zeros() {
        let u = RowUpdate::Dense(vec![0.0, 1.0, 0.0, -2.0]);
        let got: Vec<_> = u.iter_nonzero().collect();
        assert_eq!(got, vec![(1, 1.0), (3, -2.0)]);
    }

    #[test]
    fn wire_bytes_reflect_representation() {
        assert_eq!(RowUpdate::Dense(vec![0.0; 10]).wire_bytes(), 40);
        assert_eq!(RowUpdate::Sparse(vec![(1, 1.0)]).wire_bytes(), 8);
        assert_eq!(RowData::Dense(vec![0.0; 3]).wire_bytes(), 12);
    }
}
