//! The simulated network: a virtual-time, fault-injecting [`Transport`].
//!
//! Guarantees the same contract the production bus gives the stack —
//! **per-directed-link FIFO** and **exactly-once** delivery — while
//! injecting latency, jitter, retransmission delay and duplicate copies
//! (filtered at the receiver edge by link sequence number). Cross-link
//! ordering is deliberately unconstrained: jitter reorders freely, which
//! is exactly the asynchrony the consistency bounds must survive.
//!
//! Everything is scheduled on one binary heap ordered by
//! `(delivery time, global sequence)`; the global sequence is monotone in
//! send order, so same-instant deliveries on one link stay FIFO and the
//! whole schedule is a pure function of (seed, send sequence).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::{Arc, Mutex};

use crate::comm::bus::Transport;
use crate::comm::Msg;
use crate::error::Result;
use crate::metrics::NetMetrics;
use crate::types::NodeId;
use crate::util::Rng64;

use super::FaultConfig;

/// Delivery counters for one run (reported in [`super::SimReport`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct SimNetStats {
    /// Messages accepted by `send`.
    pub sent: u64,
    /// Messages delivered to a node.
    pub delivered: u64,
    /// Messages that paid the retransmission delay ("dropped once").
    pub delayed_retrans: u64,
    /// Duplicate copies injected.
    pub duplicates_injected: u64,
    /// Duplicate copies filtered at the receiver edge.
    pub duplicates_filtered: u64,
    /// In-flight messages destroyed by a crash ([`SimNet::purge_to`]).
    pub purged: u64,
}

/// One scheduled delivery. Ordered by `(at, seq)`; `seq` is globally
/// unique so the order is total and deterministic.
struct InFlight {
    at: u64,
    seq: u64,
    link_seq: u64,
    msg: Msg,
}

impl PartialEq for InFlight {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for InFlight {}
impl PartialOrd for InFlight {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for InFlight {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Per-directed-link state.
#[derive(Default)]
struct LinkState {
    /// Next link sequence number to assign at send.
    send_seq: u64,
    /// Next link sequence number the receiver expects.
    deliver_seq: u64,
    /// Latest scheduled delivery time (FIFO floor for later sends).
    last_sched: u64,
}

struct Inner {
    now: u64,
    seq: u64,
    heap: BinaryHeap<Reverse<InFlight>>,
    links: HashMap<(NodeId, NodeId), LinkState>,
    rng: Rng64,
    faults: FaultConfig,
    stats: SimNetStats,
}

impl Inner {
    /// Pop filtered-duplicate heap entries off the top. A duplicate is any
    /// entry whose link sequence the receiver has already consumed; since
    /// a copy is always scheduled strictly after its original, the
    /// original is consumed first and the copy surfaces here.
    fn prune(&mut self) {
        while let Some(Reverse(f)) = self.heap.peek() {
            let link = (f.msg.src, f.msg.dst);
            let expected = self.links.get(&link).map_or(0, |l| l.deliver_seq);
            if f.link_seq < expected {
                self.heap.pop();
                self.stats.duplicates_filtered += 1;
            } else {
                break;
            }
        }
    }
}

/// Virtual-time fault-injecting transport. Wrap in `Arc` and hand to
/// [`crate::comm::NetSender::from_transport`]; the harness keeps a second
/// `Arc` for the event loop.
pub struct SimNet {
    inner: Mutex<Inner>,
    metrics: Arc<NetMetrics>,
}

impl SimNet {
    /// New network; `seed` must derive from the run's master seed by fixed
    /// mixing so the fault schedule is reproducible.
    pub fn new(seed: u64, faults: FaultConfig) -> Self {
        Self::new_with_metrics(seed, faults, Arc::new(NetMetrics::default()))
    }

    /// Same, but recording into an externally constructed metrics handle —
    /// the harness registers it on the run's shared registry so net counters
    /// appear in the per-run snapshot.
    pub fn new_with_metrics(seed: u64, faults: FaultConfig, metrics: Arc<NetMetrics>) -> Self {
        SimNet {
            inner: Mutex::new(Inner {
                now: 0,
                seq: 0,
                heap: BinaryHeap::new(),
                links: HashMap::new(),
                rng: Rng64::seed_from_u64(seed),
                faults,
                stats: SimNetStats::default(),
            }),
            metrics,
        }
    }

    /// Earliest pending delivery time, if any traffic is in flight.
    pub fn next_arrival(&self) -> Option<u64> {
        let mut g = self.inner.lock().unwrap();
        g.prune();
        g.heap.peek().map(|Reverse(f)| f.at)
    }

    /// Deliver the next message: advances virtual time to its arrival and
    /// returns `(arrival time, message)`. `None` when the network is idle.
    pub fn pop_next(&self) -> Option<(u64, Msg)> {
        let mut g = self.inner.lock().unwrap();
        g.prune();
        let Reverse(f) = g.heap.pop()?;
        let link = (f.msg.src, f.msg.dst);
        let l = g.links.get_mut(&link).expect("delivery on unknown link");
        // `>=`, not `==`: a crash purge may have destroyed intermediate
        // link sequence numbers; order must still be monotone.
        debug_assert!(f.link_seq >= l.deliver_seq, "per-link FIFO broken in SimNet");
        l.deliver_seq = f.link_seq + 1;
        g.now = g.now.max(f.at);
        g.stats.delivered += 1;
        self.metrics.record_deliver(f.msg.payload.kind());
        self.metrics.set_inflight(g.heap.len());
        Some((f.at, f.msg))
    }

    /// Advance virtual time (worker steps move time; the network only
    /// needs to know so later sends are scheduled after `t`).
    pub fn advance_to(&self, t: u64) {
        let mut g = self.inner.lock().unwrap();
        g.now = g.now.max(t);
    }

    /// Current virtual time.
    pub fn now(&self) -> u64 {
        self.inner.lock().unwrap().now
    }

    /// True when nothing (not even a filtered duplicate) is in flight.
    pub fn is_empty(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        g.prune();
        g.heap.is_empty()
    }

    /// Counter snapshot.
    pub fn stats(&self) -> SimNetStats {
        self.inner.lock().unwrap().stats
    }

    /// Crash semantics: destroy every in-flight message addressed to
    /// `node` (a dead process receives nothing, and nothing it would have
    /// received survives its restart). Messages *from* the node that are
    /// already on the wire still arrive — they left before the crash.
    /// Returns how many messages were destroyed.
    pub fn purge_to(&self, node: NodeId) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let drained: Vec<Reverse<InFlight>> = std::mem::take(&mut g.heap).into_vec();
        let mut purged = 0;
        for e in drained {
            if e.0.msg.dst == node {
                purged += 1;
            } else {
                g.heap.push(e);
            }
        }
        g.stats.purged += purged;
        purged
    }
}

impl Transport for SimNet {
    fn send(&self, msg: Msg) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let f = g.faults;
        let mut delay = f.latency_us;
        if f.jitter_us > 0 {
            delay += g.rng.range_u64(0, f.jitter_us);
        }
        if f.drop_p > 0.0 && g.rng.chance(f.drop_p) {
            delay += f.retrans_us;
            g.stats.delayed_retrans += 1;
        }
        let link = (msg.src, msg.dst);
        let floor = g.links.entry(link).or_default().last_sched;
        // ≥ 1 µs so a delivery never lands at its own send instant; the
        // FIFO floor keeps per-link order under jitter/retransmission.
        let at = (g.now + delay.max(1)).max(floor);
        let l = g.links.get_mut(&link).unwrap();
        l.last_sched = at;
        let link_seq = l.send_seq;
        l.send_seq += 1;

        self.metrics.record_send(msg.payload.kind(), msg.payload.wire_bytes());
        g.stats.sent += 1;

        let dup = f.dup_p > 0.0 && g.rng.chance(f.dup_p);
        let dup_msg = if dup { Some(msg.clone()) } else { None };
        let seq = g.seq;
        g.seq += 1;
        g.heap.push(Reverse(InFlight { at, seq, link_seq, msg }));
        if let Some(m) = dup_msg {
            // Same link_seq: the receiver-edge filter drops it. Scheduled
            // strictly after the original; does not move the FIFO floor.
            let dup_at = at + 1 + f.dup_extra_us;
            let dup_seq = g.seq;
            g.seq += 1;
            g.stats.duplicates_injected += 1;
            g.heap.push(Reverse(InFlight { at: dup_at, seq: dup_seq, link_seq, msg: m }));
        }
        self.metrics.set_inflight(g.heap.len());
        Ok(())
    }

    fn metrics(&self) -> Arc<NetMetrics> {
        self.metrics.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::msg::Payload;
    use crate::types::{ProcId, ShardId};

    fn msg(src: u32, dst: u32, clock: u32) -> Msg {
        Msg {
            src: NodeId::Client(ProcId(src)),
            dst: NodeId::Server(ShardId(dst)),
            payload: Payload::ClockNotify { proc: ProcId(src), clock, epoch: 0 },
        }
    }

    fn drain(net: &SimNet) -> Vec<(u64, Msg)> {
        let mut out = Vec::new();
        while let Some(d) = net.pop_next() {
            out.push(d);
        }
        out
    }

    #[test]
    fn per_link_fifo_survives_jitter_and_retrans() {
        let faults = FaultConfig { jitter_us: 500, drop_p: 0.3, retrans_us: 400, ..FaultConfig::chaos() };
        let net = SimNet::new(7, faults);
        for i in 0..200 {
            net.send(msg(0, 0, i)).unwrap();
        }
        let got = drain(&net);
        assert_eq!(got.len(), 200);
        let mut prev_at = 0;
        for (i, (at, m)) in got.iter().enumerate() {
            assert!(*at >= prev_at, "arrival times monotone on one link");
            prev_at = *at;
            match m.payload {
                Payload::ClockNotify { clock, .. } => assert_eq!(clock, i as u32, "FIFO order"),
                _ => unreachable!(),
            }
        }
        assert!(net.is_empty());
    }

    #[test]
    fn cross_link_reordering_happens() {
        let faults = FaultConfig { latency_us: 10, jitter_us: 1000, ..FaultConfig::none() };
        let net = SimNet::new(3, faults);
        // Interleave sends on two links; with jitter 100× latency some
        // pair must arrive out of send order.
        for i in 0..50 {
            net.send(msg(0, 0, i)).unwrap();
            net.send(msg(1, 0, i)).unwrap();
        }
        let got = drain(&net);
        assert_eq!(got.len(), 100);
        let sent_order: Vec<u32> = (0..50).flat_map(|i| [i, i]).collect();
        let arrived: Vec<u32> = got
            .iter()
            .map(|(_, m)| match m.payload {
                Payload::ClockNotify { clock, .. } => clock,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(arrived, sent_order, "jitter should reorder across links");
    }

    #[test]
    fn duplicates_are_injected_and_filtered() {
        let faults = FaultConfig { dup_p: 1.0, dup_extra_us: 5, ..FaultConfig::none() };
        let net = SimNet::new(11, faults);
        for i in 0..20 {
            net.send(msg(0, 0, i)).unwrap();
        }
        let got = drain(&net);
        assert_eq!(got.len(), 20, "every message delivered exactly once");
        let s = net.stats();
        assert_eq!(s.duplicates_injected, 20);
        assert_eq!(s.duplicates_filtered, 20);
        assert_eq!(s.delivered, 20);
    }

    #[test]
    fn identical_seed_identical_schedule() {
        let mk = || {
            let net = SimNet::new(42, FaultConfig::chaos());
            for i in 0..100 {
                net.send(msg(i % 3, i % 2, i)).unwrap();
            }
            drain(&net)
                .into_iter()
                .map(|(at, m)| (at, format!("{:?}", m.payload.kind()), m.src, m.dst))
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn purge_destroys_only_traffic_to_the_node() {
        let net = SimNet::new(5, FaultConfig::none());
        for i in 0..10 {
            net.send(msg(0, 0, i)).unwrap(); // to shard 0 (will crash)
            net.send(msg(0, 1, i)).unwrap(); // to shard 1 (survives)
        }
        let purged = net.purge_to(NodeId::Server(ShardId(0)));
        assert_eq!(purged, 10);
        assert_eq!(net.stats().purged, 10);
        let got = drain(&net);
        assert_eq!(got.len(), 10);
        for (_, m) in &got {
            assert_eq!(m.dst, NodeId::Server(ShardId(1)));
        }
        // Post-restart traffic on the purged link flows despite the gap
        // in link sequence numbers.
        for i in 0..5 {
            net.send(msg(0, 0, 100 + i)).unwrap();
        }
        let after = drain(&net);
        assert_eq!(after.len(), 5);
        let clocks: Vec<u32> = after
            .iter()
            .map(|(_, m)| match m.payload {
                Payload::ClockNotify { clock, .. } => clock,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(clocks, vec![100, 101, 102, 103, 104], "FIFO resumes after the gap");
    }

    #[test]
    fn time_only_moves_forward() {
        let net = SimNet::new(1, FaultConfig::none());
        net.send(msg(0, 0, 0)).unwrap();
        let (at, _) = net.pop_next().unwrap();
        assert!(at >= 1);
        net.advance_to(1000);
        net.send(msg(0, 0, 1)).unwrap();
        let (at2, _) = net.pop_next().unwrap();
        assert!(at2 > 1000, "sends after advance land after it");
    }
}
