//! Deterministic simulation harness with fault injection.
//!
//! Drives the **real** client/server/consistency stack — [`crate::client::ClientCore`],
//! [`crate::server::ServerShard`], the [`crate::consistency`] gates and the
//! [`crate::clock`] vector clocks — through a simulated transport
//! ([`net::SimNet`]) that implements the production [`crate::comm::Transport`]
//! surface. No threads: one virtual-time event loop interleaves message
//! deliveries and worker steps, so every run is a deterministic function of
//! `(SimConfig, seed)`.
//!
//! ## Determinism contract
//!
//! * All randomness — fault injection, workloads, straggler jitter — flows
//!   from a single [`crate::util::Rng64`] lineage seeded by `SimConfig::seed`
//!   (the network and each worker get independent streams derived from it by
//!   fixed mixing, never from wall-clock or iteration order).
//! * Time is virtual (µs, `u64`). Events are ordered lexicographically by
//!   `(time, sequence-number)`; a global monotone sequence number breaks
//!   ties, and **message deliveries win ties against worker steps** so the
//!   rule is total.
//! * The stack itself emits messages purely as a function of its state:
//!   every multi-recipient iteration in client/server code is sorted
//!   (see the determinism notes in `client::core` and
//!   `server::visibility`), and the simulated network preserves per-link
//!   FIFO exactly like the production bus.
//!
//! Consequence: identical seed + config ⇒ **byte-identical event trace**
//! (and therefore identical [`SimReport::trace_hash`]). The suite asserts
//! this on every policy.
//!
//! ## Fault model
//!
//! [`FaultConfig`] injects, per message: base latency, uniform jitter,
//! probabilistic extra retransmission delay (a "drop" whose retry is folded
//! into one longer delay — the link stays exactly-once and FIFO, like TCP),
//! and duplicate deliveries (filtered at the receiver edge by link sequence
//! number, like TCP's). `SimConfig::stragglers` slows chosen workers by a
//! multiplier. None of this may violate the paper's bounds — that is the
//! point.
//!
//! ## Oracles
//!
//! [`harness::Oracle`] checks, on every run, from independent mirrors (it
//! never trusts client-internal ledgers):
//!
//! * **staleness** — SSP/CAP/CVAP reads never observe a row older than
//!   `c − s − 1` (computed with the oracle's own saturating arithmetic);
//! * **value bound** — VAP/CVAP per-parameter pending mass never exceeds
//!   `max(v_thr, u_obs)` past the write gate;
//! * **read-my-writes** and per-worker **FIFO** for every policy;
//! * **divergence** — replica views stay within
//!   [`crate::consistency::ConsistencyModel::divergence_bound`];
//! * **quiescence** — after drain: all replicas byte-equal to the servers
//!   (exactly, not approximately: workloads use dyadic deltas so f32
//!   sums are exact).
//!
//! ## Reproducing a failing seed
//!
//! A sweep failure report names the seed. To reproduce:
//!
//! ```no_run
//! use bapps::sim::{Sim, SimConfig};
//! let cfg = SimConfig::default().with_seed(0xBAD5EED);
//! let report = Sim::run(&cfg);            // byte-identical every time
//! eprintln!("{}", report.describe());     // violations + trace tail
//! ```
//!
//! [`sweep::shrink`] then minimizes the schedule: it greedily disables
//! fault classes and shrinks the workload while the failure persists,
//! yielding the smallest configuration (and its trace) that still fails.

pub mod harness;
pub mod net;
pub mod sweep;
pub mod vtrace;

pub use harness::{Oracle, Sim, SimReport, Violation};
pub use net::{SimNet, SimNetStats};
pub use sweep::{ablate, shrink, sweep, AblationArm, AblationReport, SweepOutcome};
pub use vtrace::SimTrace;

use crate::config::PolicyConfig;

/// One injected shard crash. The shard process dies at `at_us`: its
/// entire in-memory state is discarded and every in-flight message
/// addressed to it is destroyed. The coordinator's failure detector
/// notices the silence (missed heartbeats) and respawns the shard from
/// its checkpoint + WAL once it has been down at least
/// `restart_after_us`.
#[derive(Debug, Clone, Copy)]
pub struct CrashFault {
    /// Which shard dies.
    pub shard: u32,
    /// Virtual time of death (µs).
    pub at_us: u64,
    /// Minimum downtime before the respawn can succeed (µs).
    pub restart_after_us: u64,
}

/// Per-message fault injection knobs. All delays in virtual µs.
#[derive(Debug, Clone, Copy)]
pub struct FaultConfig {
    /// Base one-way latency added to every message.
    pub latency_us: u64,
    /// Uniform extra jitter in `[0, jitter_us]` (reorders across links;
    /// per-link FIFO is re-imposed after delay assignment).
    pub jitter_us: u64,
    /// Probability a message is "dropped" and must be retransmitted. The
    /// retry is folded into one longer delay of `+retrans_us`, keeping the
    /// link exactly-once.
    pub drop_p: f64,
    /// Extra delay a dropped message pays.
    pub retrans_us: u64,
    /// Probability a duplicate copy of a message is injected after it.
    /// Duplicates carry the same link sequence number and are filtered at
    /// the receiver edge — they stress the filter, not the stack.
    pub dup_p: f64,
    /// How long after the original the duplicate lands.
    pub dup_extra_us: u64,
    /// Optional shard crash + recovery.
    pub crash: Option<CrashFault>,
}

impl FaultConfig {
    /// No faults: fixed small latency, nothing else.
    pub fn none() -> Self {
        FaultConfig {
            latency_us: 5,
            jitter_us: 0,
            drop_p: 0.0,
            retrans_us: 0,
            dup_p: 0.0,
            dup_extra_us: 0,
            crash: None,
        }
    }

    /// The default chaos mix used by the sweeps: latency comparable to the
    /// op cost, jitter well above it (heavy cross-link reordering), 5%
    /// drops with a long retransmit, 5% duplicates.
    pub fn chaos() -> Self {
        FaultConfig {
            latency_us: 50,
            jitter_us: 120,
            drop_p: 0.05,
            retrans_us: 300,
            dup_p: 0.05,
            dup_extra_us: 90,
            crash: None,
        }
    }
}

/// Deliberately broken invariants for oracle self-tests: a harness whose
/// oracles never fire proves nothing, so the suite runs sabotaged
/// configurations and asserts they are caught.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sabotage {
    /// Healthy run.
    None,
    /// Workers read with `reader_clock = 0`, so the client-side staleness
    /// gate trivially passes while the oracle still judges reads against
    /// the worker's true clock. Under latency this must trip the
    /// staleness oracle.
    ReadGate,
    /// Writes go through [`crate::client::ClientCore::sabotage_inc`],
    /// skipping the VAP write gate. Must trip the value-bound oracle.
    WriteGate,
    /// The recovered shard skips WAL replay (checkpoint only): every push
    /// applied since the last checkpoint is silently lost server-side.
    /// Must trip the quiescence oracle on a run with a crash.
    SkipWalReplay,
}

/// Full description of one simulated run. `Default` is the standard small
/// topology (2 procs × 2 workers, 2 shards) under chaos faults.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Master seed; the only source of randomness.
    pub seed: u64,
    /// Consistency policy for the single simulated table.
    pub policy: PolicyConfig,
    /// Client processes.
    pub procs: u32,
    /// Worker threads per process.
    pub threads_per_proc: u32,
    /// Server shards.
    pub shards: u32,
    /// Shared rows workers contend on (plus 1 FIFO row and one private
    /// row per worker, allocated after them).
    pub shared_rows: u64,
    /// Columns per row (≥ 2; the FIFO check uses columns 0 and 1).
    pub cols: u32,
    /// Clock periods each worker runs.
    pub rounds: u32,
    /// Random ops per worker between clocks.
    pub ops_per_round: usize,
    /// Virtual cost of one op (µs).
    pub op_cost_us: u64,
    /// `(worker index, slowdown multiplier)` stragglers.
    pub stragglers: Vec<(u32, f64)>,
    /// Network fault injection.
    pub faults: FaultConfig,
    /// Oracle self-test mode.
    pub sabotage: Sabotage,
    /// Virtual-time eager-flusher period (µs; 0 = off). When set, every
    /// client core's [`crate::client::ClientCore::flush_eager_tables`]
    /// runs on this cadence — the simulation analogue of the production
    /// flusher thread, so CAP/VAP eager propagation is exercised between
    /// clock boundaries.
    pub flusher_every_us: u64,
    /// Coordinator → shard heartbeat period (µs). Only consulted when a
    /// crash is configured.
    pub heartbeat_every_us: u64,
    /// Silence window after which the coordinator declares a shard dead.
    /// Must exceed the worst-case chaos round trip or a live shard gets
    /// falsely declared.
    pub heartbeat_deadline_us: u64,
    /// Shard checkpoint cadence in WAL records (0 = never; recovery then
    /// replays the full WAL).
    pub checkpoint_every: u64,
    /// Magnitude-priority egress ordering (paper §4.2); `false` = FIFO.
    /// The ablation flips this on otherwise-identical seeds.
    pub priority: bool,
    /// Rows the virtual-time flusher drains per table per tick
    /// (`usize::MAX` = everything). Partial drains keep the egress queue
    /// populated so the drain *order* is actually observable.
    pub flush_max_rows: usize,
    /// Shard apply-path worker threads. The pool preserves per-row apply
    /// order, so any value must leave every per-seed snapshot byte-identical
    /// to `1` — the determinism suite pins exactly that. Default 1 (inline).
    pub apply_threads: u32,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            seed: 1,
            policy: PolicyConfig::Ssp { staleness: 1 },
            procs: 2,
            threads_per_proc: 2,
            shards: 2,
            shared_rows: 6,
            cols: 3,
            rounds: 8,
            ops_per_round: 6,
            op_cost_us: 20,
            stragglers: Vec::new(),
            faults: FaultConfig::chaos(),
            sabotage: Sabotage::None,
            flusher_every_us: 0,
            heartbeat_every_us: 400,
            heartbeat_deadline_us: 2_500,
            checkpoint_every: 16,
            priority: true,
            flush_max_rows: usize::MAX,
            apply_threads: 1,
        }
    }
}

impl SimConfig {
    /// Same run, different seed (the sweep/shrink workhorse).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Same run, different policy.
    pub fn with_policy(mut self, policy: PolicyConfig) -> Self {
        self.policy = policy;
        self
    }

    /// Same run, plus one shard crash at `at_us` with a minimum downtime
    /// of `restart_after_us`.
    pub fn with_crash(mut self, shard: u32, at_us: u64, restart_after_us: u64) -> Self {
        self.faults.crash = Some(CrashFault { shard, at_us, restart_after_us });
        self
    }

    /// Same run, magnitude priority on/off (the E6 ablation knob).
    pub fn with_priority(mut self, on: bool) -> Self {
        self.priority = on;
        self
    }

    /// Same run, flusher drains at most `rows` rows per table per tick.
    pub fn with_flush_max_rows(mut self, rows: usize) -> Self {
        self.flush_max_rows = rows;
        self
    }

    /// Total worker count.
    pub fn num_workers(&self) -> u32 {
        self.procs * self.threads_per_proc
    }

    /// Row layout: shared rows first, then the FIFO row, then one private
    /// row per worker.
    pub fn fifo_row(&self) -> u64 {
        self.shared_rows
    }

    /// The private read-my-writes row of `worker`.
    pub fn own_row(&self, worker: u32) -> u64 {
        self.shared_rows + 1 + worker as u64
    }

    /// Total rows in the simulated table.
    pub fn num_rows(&self) -> u64 {
        self.shared_rows + 1 + self.num_workers() as u64
    }
}
