//! Seed sweeps and failing-schedule shrinking.
//!
//! [`sweep`] runs one configuration across a seed range and collects every
//! failing report. [`shrink`] takes a failing configuration and greedily
//! simplifies it — disabling fault classes, dropping stragglers, shrinking
//! the workload — keeping each simplification only if the failure
//! persists, so the survivor is a minimal reproduction to debug against
//! (determinism makes every re-run exact).

use crate::metrics::Snapshot;

use super::harness::{Sim, SimReport};
use super::{FaultConfig, SimConfig};

/// Result of a seed sweep.
#[derive(Debug)]
pub struct SweepOutcome {
    /// Runs executed.
    pub runs: u64,
    /// `(seed, report)` for every failing run.
    pub failures: Vec<(u64, SimReport)>,
    /// `(seed, metrics snapshot)` for **every** run, failing or not —
    /// the per-run observability record the smoke suite serializes.
    pub snapshots: Vec<(u64, Snapshot)>,
}

impl SweepOutcome {
    /// Did every run uphold every bound?
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    /// One line per failure (for assertion messages).
    pub fn describe(&self) -> String {
        if self.ok() {
            return format!("{} runs, no violations", self.runs);
        }
        let mut s = format!("{} of {} runs failed:\n", self.failures.len(), self.runs);
        for (seed, rep) in &self.failures {
            s.push_str(&format!("--- seed {seed} ---\n{}", rep.describe()));
        }
        s
    }
}

/// Run `base` across `seeds`, collecting failures.
pub fn sweep(base: &SimConfig, seeds: std::ops::Range<u64>) -> SweepOutcome {
    let mut runs = 0;
    let mut failures = Vec::new();
    let mut snapshots = Vec::new();
    for seed in seeds {
        runs += 1;
        let report = Sim::run(&base.clone().with_seed(seed));
        if !report.ok() {
            // Re-run with trace storage so the failure report carries a
            // schedule tail (identical by determinism).
            failures.push((seed, Sim::run_traced(&base.clone().with_seed(seed))));
        }
        snapshots.push((seed, report.snapshot));
    }
    SweepOutcome { runs, failures, snapshots }
}

/// One arm of the magnitude-priority ablation: aggregates over all seeds
/// run with the same `priority` setting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AblationArm {
    /// Egress drain order this arm ran with (`true` = magnitude).
    pub priority: bool,
    /// Seeds run.
    pub runs: u64,
    /// Runs with oracle violations (must be 0 either way — the ablation
    /// compares performance signals, never correctness).
    pub failures: u64,
    /// Σ `sim_gate_retries_total{gate="write"}` — write-gate blocks.
    pub write_blocks: u64,
    /// Σ `sim_blocked_us{gate="write"}` — virtual µs writers sat blocked.
    pub write_blocked_us: u64,
    /// Σ `client_egress_reorders_total` — rows that overtook older rows.
    pub egress_reorders: u64,
}

/// Outcome of [`ablate`]: the same seeds, magnitude priority on vs. off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AblationReport {
    /// Magnitude-priority arm.
    pub on: AblationArm,
    /// FIFO arm.
    pub off: AblationArm,
}

impl AblationReport {
    /// Both arms violation-free?
    pub fn ok(&self) -> bool {
        self.on.failures == 0 && self.off.failures == 0
    }

    /// Two-line comparison for logs. Deltas are reported, not asserted:
    /// which order blocks less is workload-dependent; what the harness
    /// guarantees is that both orders uphold every bound.
    pub fn describe(&self) -> String {
        let line = |a: &AblationArm| {
            format!(
                "priority={} runs={} failures={} write_blocks={} write_blocked_us={} \
                 egress_reorders={}",
                a.priority,
                a.runs,
                a.failures,
                a.write_blocks,
                a.write_blocked_us,
                a.egress_reorders
            )
        };
        format!("{}
{}", line(&self.on), line(&self.off))
    }
}

/// Run `base` across `seeds` twice — magnitude priority on, then off —
/// and aggregate the gate/blocking metrics of each arm (ablation E6).
///
/// The base configuration is nudged toward partial drains (flusher on,
/// one row per tick) so the egress queue actually holds several rows and
/// the drain *order* is observable; with whole-queue drains both orders
/// ship identical batches.
pub fn ablate(base: &SimConfig, seeds: std::ops::Range<u64>) -> AblationReport {
    let mut arm_cfg = base.clone().with_flush_max_rows(1);
    if arm_cfg.flusher_every_us == 0 {
        arm_cfg.flusher_every_us = 60;
    }
    let run_arm = |priority: bool| {
        let mut arm = AblationArm {
            priority,
            runs: 0,
            failures: 0,
            write_blocks: 0,
            write_blocked_us: 0,
            egress_reorders: 0,
        };
        for seed in seeds.clone() {
            let r = Sim::run(&arm_cfg.clone().with_priority(priority).with_seed(seed));
            arm.runs += 1;
            if !r.ok() {
                arm.failures += 1;
            }
            let gate_write: &[(&str, &str)] = &[("gate", "write")];
            let blocks = r.snapshot.counter("sim_gate_retries_total", gate_write);
            arm.write_blocks += blocks.unwrap_or(0);
            let blocked = r.snapshot.counter("sim_blocked_us", gate_write);
            arm.write_blocked_us += blocked.unwrap_or(0);
            arm.egress_reorders += r.snapshot.counter_sum("client_egress_reorders_total");
        }
        arm
    };
    AblationReport { on: run_arm(true), off: run_arm(false) }
}

/// Candidate simplifications, most aggressive first. Each either disables
/// a fault class, removes stragglers, or shrinks the workload.
fn candidates(c: &SimConfig) -> Vec<SimConfig> {
    let mut out = Vec::new();
    if c.faults.crash.is_some() {
        let mut n = c.clone();
        n.faults = FaultConfig { crash: None, ..n.faults };
        out.push(n);
    }
    if c.faults.dup_p > 0.0 {
        let mut n = c.clone();
        n.faults = FaultConfig { dup_p: 0.0, ..n.faults };
        out.push(n);
    }
    if c.faults.drop_p > 0.0 {
        let mut n = c.clone();
        n.faults = FaultConfig { drop_p: 0.0, ..n.faults };
        out.push(n);
    }
    if c.faults.jitter_us > 0 {
        let mut n = c.clone();
        n.faults = FaultConfig { jitter_us: 0, ..n.faults };
        out.push(n);
    }
    if !c.stragglers.is_empty() {
        let mut n = c.clone();
        n.stragglers = Vec::new();
        out.push(n);
    }
    if c.rounds > 1 {
        let mut n = c.clone();
        n.rounds /= 2;
        out.push(n);
    }
    if c.ops_per_round > 1 {
        let mut n = c.clone();
        n.ops_per_round /= 2;
        out.push(n);
    }
    if c.shared_rows > 1 {
        let mut n = c.clone();
        n.shared_rows /= 2;
        out.push(n);
    }
    out
}

/// Greedily minimize a failing configuration. Returns the simplest
/// configuration that still fails together with its (traced) report.
/// `cfg` itself must fail; if it does not, it is returned unchanged with
/// its clean report.
pub fn shrink(cfg: &SimConfig) -> (SimConfig, SimReport) {
    let mut cur = cfg.clone();
    let mut rep = Sim::run_traced(&cur);
    if rep.ok() {
        return (cur, rep);
    }
    loop {
        let mut progressed = false;
        for cand in candidates(&cur) {
            let r = Sim::run_traced(&cand);
            if !r.ok() {
                cur = cand;
                rep = r;
                progressed = true;
                break;
            }
        }
        if !progressed {
            break;
        }
    }
    (cur, rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::sim::Sabotage;

    #[test]
    fn sweep_collects_no_failures_on_clean_config() {
        let out = sweep(&SimConfig::default(), 100..108);
        assert!(out.ok(), "{}", out.describe());
        assert_eq!(out.runs, 8);
        assert_eq!(out.snapshots.len(), 8, "every run carries a metric snapshot");
        for (seed, snap) in &out.snapshots {
            assert!(snap.counter_sum("shard_pushes_applied_total") > 0, "seed {seed}: no pushes");
        }
    }

    #[test]
    fn ablation_runs_both_arms_clean_and_deterministic() {
        let base =
            SimConfig::default().with_policy(PolicyConfig::Vap { v_thr: 1.0, strong: false });
        let a = ablate(&base, 300..302);
        assert!(a.ok(), "{}", a.describe());
        assert_eq!(a.on.runs, 2);
        assert_eq!(a.off.runs, 2);
        // Only the magnitude arm can reorder egress; FIFO reports zero by
        // construction, and the whole report replays exactly.
        assert_eq!(a.off.egress_reorders, 0, "{}", a.describe());
        assert_eq!(a, ablate(&base, 300..302), "ablation must be deterministic");
    }

    #[test]
    fn shrink_minimizes_a_sabotaged_failure() {
        // The write-gate sabotage fails under any schedule, so the
        // shrinker should strip every fault class and most of the
        // workload while the failure persists.
        let mut cfg = SimConfig::default()
            .with_policy(PolicyConfig::Vap { v_thr: 1.0, strong: false })
            .with_seed(4);
        cfg.sabotage = Sabotage::WriteGate;
        let (min_cfg, rep) = shrink(&cfg);
        assert!(!rep.ok(), "shrunk config must still fail");
        assert_eq!(min_cfg.faults.dup_p, 0.0, "duplicates eliminated");
        assert_eq!(min_cfg.faults.drop_p, 0.0, "drops eliminated");
        assert_eq!(min_cfg.faults.jitter_us, 0, "jitter eliminated");
        assert!(min_cfg.rounds <= cfg.rounds / 2, "workload shrunk");
        assert!(!rep.trace_tail.is_empty(), "shrunk report carries a trace tail");
    }

    #[test]
    fn shrink_returns_clean_config_unchanged() {
        let cfg = SimConfig::default().with_seed(21);
        let (min_cfg, rep) = shrink(&cfg);
        assert!(rep.ok());
        assert_eq!(min_cfg.rounds, cfg.rounds);
    }
}
