//! Seed sweeps and failing-schedule shrinking.
//!
//! [`sweep`] runs one configuration across a seed range and collects every
//! failing report. [`shrink`] takes a failing configuration and greedily
//! simplifies it — disabling fault classes, dropping stragglers, shrinking
//! the workload — keeping each simplification only if the failure
//! persists, so the survivor is a minimal reproduction to debug against
//! (determinism makes every re-run exact).

use super::harness::{Sim, SimReport};
use super::{FaultConfig, SimConfig};

/// Result of a seed sweep.
#[derive(Debug)]
pub struct SweepOutcome {
    /// Runs executed.
    pub runs: u64,
    /// `(seed, report)` for every failing run.
    pub failures: Vec<(u64, SimReport)>,
}

impl SweepOutcome {
    /// Did every run uphold every bound?
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    /// One line per failure (for assertion messages).
    pub fn describe(&self) -> String {
        if self.ok() {
            return format!("{} runs, no violations", self.runs);
        }
        let mut s = format!("{} of {} runs failed:\n", self.failures.len(), self.runs);
        for (seed, rep) in &self.failures {
            s.push_str(&format!("--- seed {seed} ---\n{}", rep.describe()));
        }
        s
    }
}

/// Run `base` across `seeds`, collecting failures.
pub fn sweep(base: &SimConfig, seeds: std::ops::Range<u64>) -> SweepOutcome {
    let mut runs = 0;
    let mut failures = Vec::new();
    for seed in seeds {
        runs += 1;
        let report = Sim::run(&base.clone().with_seed(seed));
        if !report.ok() {
            // Re-run with trace storage so the failure report carries a
            // schedule tail (identical by determinism).
            failures.push((seed, Sim::run_traced(&base.clone().with_seed(seed))));
        }
    }
    SweepOutcome { runs, failures }
}

/// Candidate simplifications, most aggressive first. Each either disables
/// a fault class, removes stragglers, or shrinks the workload.
fn candidates(c: &SimConfig) -> Vec<SimConfig> {
    let mut out = Vec::new();
    if c.faults.crash.is_some() {
        let mut n = c.clone();
        n.faults = FaultConfig { crash: None, ..n.faults };
        out.push(n);
    }
    if c.faults.dup_p > 0.0 {
        let mut n = c.clone();
        n.faults = FaultConfig { dup_p: 0.0, ..n.faults };
        out.push(n);
    }
    if c.faults.drop_p > 0.0 {
        let mut n = c.clone();
        n.faults = FaultConfig { drop_p: 0.0, ..n.faults };
        out.push(n);
    }
    if c.faults.jitter_us > 0 {
        let mut n = c.clone();
        n.faults = FaultConfig { jitter_us: 0, ..n.faults };
        out.push(n);
    }
    if !c.stragglers.is_empty() {
        let mut n = c.clone();
        n.stragglers = Vec::new();
        out.push(n);
    }
    if c.rounds > 1 {
        let mut n = c.clone();
        n.rounds /= 2;
        out.push(n);
    }
    if c.ops_per_round > 1 {
        let mut n = c.clone();
        n.ops_per_round /= 2;
        out.push(n);
    }
    if c.shared_rows > 1 {
        let mut n = c.clone();
        n.shared_rows /= 2;
        out.push(n);
    }
    out
}

/// Greedily minimize a failing configuration. Returns the simplest
/// configuration that still fails together with its (traced) report.
/// `cfg` itself must fail; if it does not, it is returned unchanged with
/// its clean report.
pub fn shrink(cfg: &SimConfig) -> (SimConfig, SimReport) {
    let mut cur = cfg.clone();
    let mut rep = Sim::run_traced(&cur);
    if rep.ok() {
        return (cur, rep);
    }
    loop {
        let mut progressed = false;
        for cand in candidates(&cur) {
            let r = Sim::run_traced(&cand);
            if !r.ok() {
                cur = cand;
                rep = r;
                progressed = true;
                break;
            }
        }
        if !progressed {
            break;
        }
    }
    (cur, rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::sim::Sabotage;

    #[test]
    fn sweep_collects_no_failures_on_clean_config() {
        let out = sweep(&SimConfig::default(), 100..108);
        assert!(out.ok(), "{}", out.describe());
        assert_eq!(out.runs, 8);
    }

    #[test]
    fn shrink_minimizes_a_sabotaged_failure() {
        // The write-gate sabotage fails under any schedule, so the
        // shrinker should strip every fault class and most of the
        // workload while the failure persists.
        let mut cfg = SimConfig::default()
            .with_policy(PolicyConfig::Vap { v_thr: 1.0, strong: false })
            .with_seed(4);
        cfg.sabotage = Sabotage::WriteGate;
        let (min_cfg, rep) = shrink(&cfg);
        assert!(!rep.ok(), "shrunk config must still fail");
        assert_eq!(min_cfg.faults.dup_p, 0.0, "duplicates eliminated");
        assert_eq!(min_cfg.faults.drop_p, 0.0, "drops eliminated");
        assert_eq!(min_cfg.faults.jitter_us, 0, "jitter eliminated");
        assert!(min_cfg.rounds <= cfg.rounds / 2, "workload shrunk");
        assert!(!rep.trace_tail.is_empty(), "shrunk report carries a trace tail");
    }

    #[test]
    fn shrink_returns_clean_config_unchanged() {
        let cfg = SimConfig::default().with_seed(21);
        let (min_cfg, rep) = shrink(&cfg);
        assert!(rep.ok());
        assert_eq!(min_cfg.rounds, cfg.rounds);
    }
}
