//! The deterministic event loop, workloads and oracles.
//!
//! [`Sim::run`] builds the real stack — [`ClientCore`] per process,
//! [`ServerShard`] per shard — wired over a [`SimNet`], then interleaves
//! message deliveries and worker steps in virtual time. Workers run a
//! seeded random script of gated reads/writes against one table; the
//! [`Oracle`] checks every consistency bound from independent mirrors
//! (it never trusts the client's own ledgers).
//!
//! See [`crate::sim`] for the determinism contract and the fault model.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::client::ClientCore;
use crate::comm::bus::Transport;
use crate::comm::{Msg, NetSender, Payload};
use crate::config::{PolicyConfig, SystemConfig};
use crate::consistency::vap;
use crate::metrics::{CoordMetrics, NetMetrics, Registry, ShardMetrics, Snapshot};
use crate::server::{MemPersistence, ServerShard, ShardOptions, TableRegistry};
use crate::table::{RowId, RowKind, TableDesc, TableId};
use crate::trace::{SpanKind, TraceClock, TraceRecorder};
use crate::types::{Clock, NodeId, ProcId, ShardId, WorkerId};
use crate::util::Rng64;

use super::net::{SimNet, SimNetStats};
use super::vtrace::SimTrace;
use super::{Sabotage, SimConfig};

/// The single simulated table.
const TABLE: TableId = TableId(0);

/// Workload deltas are dyadic (exact in f32), so every sum any replica can
/// compute is exact and order-independent — quiescence checks use `==`,
/// not tolerances.
const DELTAS: [f32; 6] = [-1.0, -0.5, -0.25, 0.25, 0.5, 1.0];

/// Violations stored per run before the run bails out (sabotage runs
/// would otherwise flood).
const MAX_VIOLATIONS: usize = 64;

/// Consecutive retries of one op before the harness declares livelock.
const RETRY_CAP: u64 = 100_000;

/// Total event budget per run (clean runs use a few thousand).
const STEP_BUDGET: u64 = 50_000_000;

/// One detected consistency-bound violation.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Virtual time (µs) of detection.
    pub at: u64,
    /// Oracle that fired: `staleness`, `value-bound`, `read-my-writes`,
    /// `fifo`, `divergence`, `batch-order`, `clock-skew`, `quiescence`,
    /// `livelock`.
    pub kind: &'static str,
    /// Human-readable specifics.
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[t={}µs] {}: {}", self.at, self.kind, self.detail)
    }
}

/// Outcome of one simulated run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Seed that produced this run (reproduces it exactly).
    pub seed: u64,
    /// Policy name (`PolicyConfig::name`).
    pub policy: String,
    /// FNV-1a fingerprint of the full event trace.
    pub trace_hash: u64,
    /// Number of trace lines (events).
    pub trace_lines: u64,
    /// Every oracle violation (empty ⇒ the run upheld all bounds).
    pub violations: Vec<Violation>,
    /// Violations dropped past [`MAX_VIOLATIONS`].
    pub violations_truncated: u64,
    /// Network delivery counters.
    pub net: SimNetStats,
    /// Successfully completed ops (including clock ticks).
    pub ops_completed: u64,
    /// Op attempts that came back gated (retried later).
    pub retries: u64,
    /// Shard crashes injected (0 or 1).
    pub crashes: u64,
    /// Deliveries destroyed because the destination shard was down (on
    /// top of [`SimNetStats::purged`] at the crash instant itself).
    pub dropped_to_dead: u64,
    /// Last trace lines (only populated by [`Sim::run_traced`]).
    pub trace_tail: Vec<String>,
    /// Point-in-time copy of the run's metrics registry, taken after the
    /// drain. Virtual-clocked, so it is a deterministic function of
    /// `(SimConfig, seed)` — byte-identical `render_json()` across runs.
    pub snapshot: Snapshot,
    /// Oracle's independent max read staleness (wire-fed mirror of the
    /// `client_read_staleness_clocks` histogram max).
    pub oracle_max_staleness: Clock,
    /// Oracle's observed max |delta| (mirror of
    /// `client_update_magnitude_max`).
    pub oracle_u_obs: f32,
    /// Oracle's count of distinct accepted push batches (mirror of
    /// `shard_pushes_applied_total`).
    pub oracle_applied_batches: u64,
    /// Perfetto JSON from the span recorder (only populated by
    /// [`Sim::run_traced`]). Virtual-clocked, so byte-identical per
    /// `(SimConfig, seed)`.
    pub trace_json: Option<String>,
}

impl SimReport {
    /// Did the run uphold every checked bound?
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Multi-line failure/summary text for logs.
    pub fn describe(&self) -> String {
        let mut s = format!(
            "seed={} policy={} events={} hash={:016x} ops={} retries={} \
             sent={} delivered={} retrans={} dup_inj={} dup_filt={} \
             crashes={} purged={} dropped_dead={}\n",
            self.seed,
            self.policy,
            self.trace_lines,
            self.trace_hash,
            self.ops_completed,
            self.retries,
            self.net.sent,
            self.net.delivered,
            self.net.delayed_retrans,
            self.net.duplicates_injected,
            self.net.duplicates_filtered,
            self.crashes,
            self.net.purged,
            self.dropped_to_dead,
        );
        if self.ok() {
            s.push_str("no violations\n");
        } else {
            s.push_str(&format!(
                "{} violation(s) (+{} truncated):\n",
                self.violations.len(),
                self.violations_truncated
            ));
            for v in &self.violations {
                s.push_str(&format!("  {v}\n"));
            }
        }
        for line in &self.trace_tail {
            s.push_str(&format!("  | {line}\n"));
        }
        s
    }
}

/// One scripted operation. `FifoWrite` is two gated increments (column 0
/// then column 1) and resumes at the blocked stage on retry so column 0 is
/// never double-applied.
#[derive(Debug, Clone, Copy)]
enum Op {
    IncShared { row: u64, col: u32, delta: f32 },
    GetShared { row: u64, col: u32 },
    IncOwn { delta: f32 },
    GetOwn,
    FifoWrite,
    FifoRead,
    Tick,
}

/// One simulated worker thread: a seeded script plus resumable op state.
struct SimWorker {
    wid: WorkerId,
    proc: usize,
    rng: Rng64,
    /// True clock mirror: number of completed `Clock()` calls. The
    /// staleness oracle judges reads against this, independent of what
    /// the worker told the gate (sabotage!).
    clock: Clock,
    round: u32,
    op_in_round: usize,
    cur: Option<Op>,
    fifo_stage: u8,
    retries_cur: u64,
    /// Exact running sum of this worker's private row (read-my-writes).
    own_expected: f32,
    cost_us: u64,
    done: bool,
}

impl SimWorker {
    /// Next scripted op, or `None` when the script is exhausted.
    fn plan_next(&mut self, cfg: &SimConfig) -> Option<Op> {
        if self.round >= cfg.rounds {
            return None;
        }
        if self.op_in_round >= cfg.ops_per_round {
            return Some(Op::Tick);
        }
        if cfg.sabotage == Sabotage::WriteGate {
            // Hammer one parameter with +1s so the pending sum provably
            // crosses any v_thr ≥ u_obs = 1 before the first release.
            return Some(Op::IncShared { row: 0, col: 0, delta: 1.0 });
        }
        let op = match self.rng.below(10) {
            0..=3 => Op::IncShared {
                row: self.rng.below(cfg.shared_rows as usize) as u64,
                col: self.rng.below(cfg.cols as usize) as u32,
                delta: DELTAS[self.rng.below(DELTAS.len())],
            },
            4 | 5 => Op::GetShared {
                row: self.rng.below(cfg.shared_rows as usize) as u64,
                col: self.rng.below(cfg.cols as usize) as u32,
            },
            6 => Op::IncOwn { delta: DELTAS[self.rng.below(DELTAS.len())] },
            7 => Op::GetOwn,
            8 => Op::FifoWrite,
            _ => Op::FifoRead,
        };
        Some(op)
    }

    fn finish_op(&mut self) {
        if matches!(self.cur, Some(Op::Tick)) {
            self.round += 1;
            self.op_in_round = 0;
        } else {
            self.op_in_round += 1;
        }
        self.cur = None;
        self.retries_cur = 0;
        self.fifo_stage = 0;
    }
}

/// Independent invariant mirrors. Fed by the harness with deliveries and
/// op outcomes; records [`Violation`]s.
pub struct Oracle {
    policy: PolicyConfig,
    /// VAP ledger mirror: signed pending sum per `(proc, row, col)`.
    /// Grows at admitted writes, shrinks when the origin's
    /// `VisibilityAck` is *delivered* — the same release point the client
    /// uses, but tracked from the wire, not from client internals.
    pending: HashMap<(u32, u64, u32), f64>,
    /// Per-param signed masses of each pushed batch, keyed
    /// `(origin, batch_id)`, recorded when the push crosses the wire.
    batch_mass: HashMap<(u32, u64), Vec<((u64, u32), f64)>>,
    /// Mirror of each shard's per-origin dedup watermark: highest batch
    /// id *applied* per `(origin, shard)`. Survives crashes exactly like
    /// the server's own (the server rebuilds it from the WAL, which holds
    /// precisely the applied prefix). Doubles as the strict batch-order
    /// check on crash-free runs.
    applied_upto: HashMap<(u32, u32), u64>,
    /// Mirror of each shard's fencing epoch (bumped on every restart).
    shard_epoch: HashMap<u32, u32>,
    /// A crash is configured: duplicate or fenced push arrivals are
    /// legitimate replay traffic, not ordering bugs.
    crash_expected: bool,
    /// Largest |delta| any worker wrote (the paper's `u`).
    pub u_obs: f32,
    /// Largest `true_clock − effective_clock` any successful gated read
    /// observed, tracked for *every* policy (the bound check only fires
    /// where the policy defines one). Independent mirror for the
    /// `client_read_staleness_clocks` histogram cross-check.
    pub max_staleness: Clock,
    /// Distinct push batches accepted (dedup'd, post-fence) across all
    /// shards — the wire-fed mirror of `shard_pushes_applied_total`.
    pub applied_batches: u64,
    /// Identity of every accepted batch, `(origin, batch_id)` — the join
    /// key for the span-tree completeness check.
    pub accepted: HashSet<(u32, u64)>,
    violations: Vec<Violation>,
    truncated: u64,
}

impl Oracle {
    /// Fresh oracle for one run under `policy`.
    pub fn new(policy: PolicyConfig) -> Self {
        Oracle {
            policy,
            pending: HashMap::new(),
            batch_mass: HashMap::new(),
            applied_upto: HashMap::new(),
            shard_epoch: HashMap::new(),
            crash_expected: false,
            u_obs: 0.0,
            max_staleness: 0,
            applied_batches: 0,
            accepted: HashSet::new(),
            violations: Vec::new(),
            truncated: 0,
        }
    }

    /// Violations recorded so far.
    pub fn violations(&self) -> &[Violation] {
        &self.violations
    }

    fn violate(&mut self, at: u64, kind: &'static str, detail: String) {
        if self.violations.len() >= MAX_VIOLATIONS {
            self.truncated += 1;
            return;
        }
        self.violations.push(Violation { at, kind, detail });
    }

    /// Observe one wire delivery (before it is dispatched to the node).
    pub fn observe_delivery(&mut self, at: u64, msg: &Msg) {
        match (&msg.payload, msg.dst) {
            (Payload::PushUpdates(b), NodeId::Server(s)) => {
                if b.epoch < self.shard_epoch.get(&s.0).copied().unwrap_or(0) {
                    // Pre-crash flush landing after the respawn: the
                    // server's epoch fence drops it, and the origin will
                    // re-send it under the new epoch.
                    return;
                }
                let key = (b.origin.0, s.0);
                if let Some(&prev) = self.applied_upto.get(&key) {
                    if b.batch_id <= prev {
                        if !self.crash_expected {
                            self.violate(
                                at,
                                "batch-order",
                                format!(
                                    "origin {} batch {} after {} at shard {}",
                                    b.origin.0, b.batch_id, prev, s.0
                                ),
                            );
                        }
                        // Retransmission of an already-applied batch: the
                        // server's per-origin dedup drops it silently.
                        return;
                    }
                }
                self.applied_upto.insert(key, b.batch_id);
                self.applied_batches += 1;
                self.accepted.insert((b.origin.0, b.batch_id));
                if self.policy.v_thr().is_some() {
                    let mut masses: Vec<((u64, u32), f64)> = Vec::new();
                    for (row, u) in b.updates.iter() {
                        for (col, v) in u.iter_nonzero() {
                            masses.push(((row.0, col), v as f64));
                        }
                    }
                    self.batch_mass.insert((b.origin.0, b.batch_id), masses);
                }
            }
            (Payload::VisibilityAck { batch_id, .. }, NodeId::Client(p)) => {
                if let Some(masses) = self.batch_mass.remove(&(p.0, *batch_id)) {
                    for ((row, col), m) in masses {
                        let e = self.pending.entry((p.0, row, col)).or_insert(0.0);
                        *e -= m;
                        if e.abs() < 1e-12 {
                            self.pending.remove(&(p.0, row, col));
                        }
                    }
                }
            }
            _ => {}
        }
    }

    /// A shard respawned: mirror its durable epoch bump so the fence
    /// check above matches the server's.
    fn on_shard_restart(&mut self, shard: u32) {
        *self.shard_epoch.entry(shard).or_insert(0) += 1;
    }

    /// Record an admitted write and check the VAP value bound: past the
    /// write gate, per-param pending mass must stay within
    /// `max(v_thr, u_obs)`.
    pub fn record_write(&mut self, at: u64, proc: u32, row: u64, col: u32, delta: f32) {
        self.u_obs = self.u_obs.max(delta.abs());
        if let Some(v_thr) = self.policy.v_thr() {
            let e = self.pending.entry((proc, row, col)).or_insert(0.0);
            *e += delta as f64;
            let sum = *e;
            let bound = v_thr.max(self.u_obs) as f64 + 1e-6;
            if sum.abs() > bound {
                self.violate(
                    at,
                    "value-bound",
                    format!(
                        "proc {proc} row {row} col {col}: |pending {sum}| > max(v_thr, u) = {bound}"
                    ),
                );
            }
        }
    }

    /// A gated read succeeded: its effective row clock must satisfy the
    /// staleness bound for the worker's *true* clock.
    pub fn check_staleness(&mut self, at: u64, wid: WorkerId, true_clock: Clock, row: u64, eff: Clock) {
        self.max_staleness = self.max_staleness.max(true_clock.saturating_sub(eff));
        if let Some(s) = self.policy.staleness() {
            let required = true_clock.saturating_sub(s.saturating_add(1));
            if eff < required {
                self.violate(
                    at,
                    "staleness",
                    format!(
                        "worker {} at clock {true_clock} read row {row} at effective clock \
                         {eff} < required {required} (s = {s})",
                        wid.0
                    ),
                );
            }
        }
    }

    /// Replica views must stay within the paper's divergence bound
    /// (checked at each clock tick for the value-bounded policies).
    ///
    /// Slack: the implementation's accounting is process-granular and
    /// signed, so transient states can exceed the *strong* bound
    /// `2·max(u, v_thr)` by the gated in-flight mass; the check allows 2×
    /// for strong (the sharp per-origin invariant is carried by
    /// [`Oracle::record_write`], and quiescence demands exact equality).
    /// The weak bound `max(u, v_thr)·P` needs no slack: every view
    /// difference decomposes into per-origin un-released pending sums,
    /// each within `max(u, v_thr)`, over at most `procs ≤ P` origins.
    pub fn check_divergence(&mut self, at: u64, cfg: &SimConfig, cores: &[ClientCore]) {
        let Some(v_thr) = self.policy.v_thr() else { return };
        let strong = matches!(
            self.policy,
            PolicyConfig::Vap { strong: true, .. } | PolicyConfig::Cvap { strong: true, .. }
        );
        let bound = vap::divergence_bound(v_thr, strong, cfg.num_workers(), self.u_obs);
        let slack = if strong { 2.0 } else { 1.0 };
        let lim = bound * slack + 1e-3;
        for row in 0..cfg.num_rows() {
            for col in 0..cfg.cols {
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for core in cores {
                    let (s, _, _, o, e) = core.debug_param(TABLE, RowId(row), col);
                    let view = s + o + e;
                    lo = lo.min(view);
                    hi = hi.max(view);
                }
                if hi - lo > lim {
                    self.violate(
                        at,
                        "divergence",
                        format!(
                            "row {row} col {col}: view spread {} > {lim} \
                             (bound {bound}, u_obs {}, strong {strong})",
                            hi - lo,
                            self.u_obs
                        ),
                    );
                    return;
                }
            }
        }
    }

    /// After drain: the network is silent, so every replica must agree
    /// exactly — with the servers, with each other, and with each
    /// worker's private running sums. Exact `==` is sound because the
    /// workload's deltas are dyadic.
    pub fn check_quiescence(
        &mut self,
        at: u64,
        cfg: &SimConfig,
        desc: &TableDesc,
        cores: &[ClientCore],
        shards: &[Option<ServerShard>],
        own_finals: &[(usize, u64, f32)],
    ) {
        let leftover: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, v)| v.abs() > 1e-9)
            .map(|((p, r, c), v)| format!("proc {p} row {r} col {c}: {v}"))
            .collect();
        for l in leftover {
            self.violate(at, "quiescence", format!("oracle ledger not drained: {l}"));
        }
        for (p, core) in cores.iter().enumerate() {
            let (mass, batches) = core.debug_pending(TABLE);
            if mass.abs() > 1e-9 || batches != 0 {
                self.violate(
                    at,
                    "quiescence",
                    format!("proc {p}: client pending mass {mass}, {batches} unacked batches"),
                );
            }
        }
        for row in 0..cfg.num_rows() {
            let shard = desc.shard_of(RowId(row), cfg.shards);
            let srow = shards[shard.0 as usize]
                .as_ref()
                .expect("shard still down at quiescence")
                .row_snapshot(TABLE, RowId(row));
            for col in 0..cfg.cols {
                let sval = srow.as_ref().and_then(|d| d.get(col)).unwrap_or(0.0);
                let mut first: Option<f32> = None;
                for (p, core) in cores.iter().enumerate() {
                    let (s, _, _, o, e) = core.debug_param(TABLE, RowId(row), col);
                    if o != 0.0 || e != 0.0 {
                        self.violate(
                            at,
                            "quiescence",
                            format!("proc {p} row {row} col {col}: overlay {o} egress {e} at rest"),
                        );
                    }
                    let view = s + o + e;
                    match first {
                        None => first = Some(view),
                        Some(f) if view != f => self.violate(
                            at,
                            "quiescence",
                            format!("row {row} col {col}: proc {p} sees {view}, proc 0 sees {f}"),
                        ),
                        _ => {}
                    }
                    if view != sval {
                        self.violate(
                            at,
                            "quiescence",
                            format!(
                                "row {row} col {col}: proc {p} view {view} != server {sval} \
                                 (shard {})",
                                shard.0
                            ),
                        );
                    }
                }
            }
        }
        for &(proc, row, expected) in own_finals {
            let (s, _, _, o, e) = cores[proc].debug_param(TABLE, RowId(row), col0());
            let view = s + o + e;
            if view != expected {
                self.violate(
                    at,
                    "read-my-writes",
                    format!("proc {proc} own row {row}: final {view} != written {expected}"),
                );
            }
        }
    }
}

/// Column the private-row ops use.
fn col0() -> u32 {
    0
}

/// The simulator entry points.
pub struct Sim;

impl Sim {
    /// Run one configuration; fingerprint-only trace (fast path for
    /// sweeps).
    pub fn run(cfg: &SimConfig) -> SimReport {
        Self::run_inner(cfg, false)
    }

    /// Run with full trace storage; the report carries a trace tail for
    /// failure forensics.
    pub fn run_traced(cfg: &SimConfig) -> SimReport {
        Self::run_inner(cfg, true)
    }

    fn run_inner(cfg: &SimConfig, keep_trace: bool) -> SimReport {
        assert!(cfg.procs >= 1 && cfg.threads_per_proc >= 1 && cfg.shards >= 1);
        assert!(cfg.cols >= 2, "FIFO oracle needs ≥ 2 columns");
        assert!(cfg.shared_rows >= 1);

        let registry = Arc::new(TableRegistry::default());
        registry
            .insert(TableDesc {
                id: TABLE,
                num_rows: cfg.num_rows(),
                row_width: cfg.cols,
                row_kind: RowKind::Dense,
                policy: cfg.policy,
            })
            .unwrap();
        let desc = registry.get(TABLE).unwrap();

        // One registry for the whole run, on a virtual clock the event
        // loop advances: every duration any layer records is a function of
        // the schedule, never of the wall — snapshots are reproducible.
        let vclock = Arc::new(AtomicU64::new(0));
        let hub = Arc::new(Registry::with_virtual_clock(vclock.clone()));
        // One span recorder for the whole cluster, on the same virtual
        // clock: every span timestamp is a function of the schedule, so
        // the Perfetto export is byte-identical per seed. Legacy events
        // stay off (the sim keeps its own line trace).
        let spans = Arc::new(TraceRecorder::with_registry(
            false,
            hub.clone(),
            TraceClock::Virtual(vclock.clone()),
            crate::trace::DEFAULT_RING_SLOTS,
        ));
        let net = Arc::new(SimNet::new_with_metrics(
            cfg.seed ^ 0x9E37_79B9_7F4A_7C15,
            cfg.faults,
            Arc::new(NetMetrics::new(&hub)),
        ));
        let transport: Arc<dyn Transport> = net.clone();
        let sender = NetSender::from_transport(transport);

        let sys = SystemConfig::builder()
            .num_server_shards(cfg.shards)
            .num_client_procs(cfg.procs)
            .threads_per_proc(cfg.threads_per_proc)
            .trace(false)
            .magnitude_priority(cfg.priority)
            .build();

        // Each shard owns a persistence handle that survives its crash:
        // the respawn recovers from exactly what its predecessor logged
        // (checkpoint + WAL), never from live memory.
        let persists: Vec<Arc<MemPersistence>> =
            (0..cfg.shards).map(|_| Arc::new(MemPersistence::new())).collect();
        let shard_opts = |s: usize| {
            let mut o = ShardOptions::new(persists[s].clone());
            o.checkpoint_every = cfg.checkpoint_every;
            o.skip_wal_replay = cfg.sabotage == Sabotage::SkipWalReplay;
            o.metrics = ShardMetrics::new(hub.clone(), s as u32);
            // Pool metrics stay unregistered under the sim regardless of
            // thread count, so snapshots carry one name set per seed.
            o.apply_threads = cfg.apply_threads;
            o
        };
        let mut shards: Vec<Option<ServerShard>> = (0..cfg.shards)
            .map(|s| {
                Some(ServerShard::with_options(
                    ShardId(s),
                    cfg.procs,
                    registry.clone(),
                    sender.clone(),
                    spans.clone(),
                    shard_opts(s as usize),
                ))
            })
            .collect();
        let cores: Vec<ClientCore> = (0..cfg.procs)
            .map(|p| {
                ClientCore::new(
                    ProcId(p),
                    sys.clone(),
                    registry.clone(),
                    sender.clone(),
                    spans.clone(),
                    hub.clone(),
                )
            })
            .collect();

        let base_cost = cfg.op_cost_us.max(1);
        let mut workers: Vec<SimWorker> = (0..cfg.num_workers())
            .map(|widx| {
                let mult = cfg
                    .stragglers
                    .iter()
                    .find(|(w, _)| *w == widx)
                    .map_or(1.0, |(_, m)| *m);
                SimWorker {
                    wid: WorkerId(widx),
                    proc: (widx / cfg.threads_per_proc) as usize,
                    // Fixed mixing off the master seed: worker streams are
                    // decorrelated by the splitmix init inside Rng64.
                    rng: Rng64::seed_from_u64(
                        cfg.seed ^ (0x517c_c1b7_2722_0a95u64.wrapping_mul(widx as u64 + 1)),
                    ),
                    clock: 0,
                    round: 0,
                    op_in_round: 0,
                    cur: None,
                    fifo_stage: 0,
                    retries_cur: 0,
                    own_expected: 0.0,
                    cost_us: ((base_cost as f64) * mult).max(1.0) as u64,
                    done: false,
                }
            })
            .collect();
        for w in &workers {
            cores[w.proc].register_worker(w.wid);
        }

        let mut trace = SimTrace::new(keep_trace);
        let mut oracle = Oracle::new(cfg.policy);
        oracle.crash_expected = cfg.faults.crash.is_some();
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = workers
            .iter()
            .enumerate()
            .map(|(i, w)| Reverse((w.cost_us, i)))
            .collect();

        let mut now: u64 = 0;
        let mut ops_completed: u64 = 0;
        let mut retries_total: u64 = 0;
        let mut steps: u64 = 0;

        // Harness-side gate observability: retry counts and blocked
        // virtual time, split by op class (each retry re-runs after one
        // op-cost quantum, so blocked time = retries × cost).
        let gate_help = "op attempts returned gated, by op class";
        let block_help = "virtual microseconds workers spent blocked on gates";
        let retries_read = hub.counter("sim_gate_retries_total", gate_help, &[("gate", "read")]);
        let retries_write = hub.counter("sim_gate_retries_total", gate_help, &[("gate", "write")]);
        let blocked_read = hub.counter("sim_blocked_us", block_help, &[("gate", "read")]);
        let blocked_write = hub.counter("sim_blocked_us", block_help, &[("gate", "write")]);
        // Coordinator-side heartbeat metrics mirror the production
        // monitor; inert (unregistered) unless a crash is configured.
        let coord_metrics = cfg.faults.crash.map(|_| CoordMetrics::new(&hub));
        let mut ping_sent_at: HashMap<u64, u64> = HashMap::new();

        // Crash/recovery machinery. All of it is inert — no events, no
        // trace lines — unless a crash is configured, so clean runs keep
        // their historical traces byte-identical.
        if let Some(c) = cfg.faults.crash {
            assert!(c.shard < cfg.shards, "crash.shard out of range");
        }
        let mut crash_pending = cfg.faults.crash;
        let mut down_shard: Option<usize> = None;
        let mut restart_at: Option<u64> = None;
        let mut next_hb = cfg.faults.crash.map(|_| cfg.heartbeat_every_us.max(1));
        let mut next_flush =
            if cfg.flusher_every_us > 0 { Some(cfg.flusher_every_us) } else { None };
        let mut last_pong: Vec<u64> = vec![0; cfg.shards as usize];
        let mut ping_seq: u64 = 0;
        let mut crashes: u64 = 0;
        let mut dropped_to_dead: u64 = 0;

        loop {
            steps += 1;
            if steps > STEP_BUDGET {
                oracle.violate(now, "livelock", "global step budget exhausted".into());
                break;
            }
            if oracle.violations.len() >= MAX_VIOLATIONS {
                break;
            }
            let tm = net.next_arrival();
            let tw = heap.peek().map(|&Reverse((t, _))| t);
            // Next system event: crash, scheduled restart, heartbeat tick,
            // flusher tick. The flusher idles once every worker script is
            // exhausted — its timer would otherwise keep the loop alive
            // forever. Lower `which` wins a same-time tie.
            let mut ts: Option<(u64, u8)> = None;
            let sys = [
                (crash_pending.map(|c| c.at_us), 0u8),
                (restart_at, 1),
                (next_hb, 2),
                (if tw.is_some() { next_flush } else { None }, 3),
            ];
            for (t, which) in sys {
                if let Some(t) = t {
                    if ts.map_or(true, |b| (t, which) < b) {
                        ts = Some((t, which));
                    }
                }
            }
            // Pick the next event class. Ties: system timers fire before
            // traffic stamped the same instant, and messages still win
            // against worker steps (the historical rule).
            let mut best: Option<(u64, u8)> = ts.map(|(t, _)| (t, 0u8));
            for (t, class) in [(tm, 1u8), (tw, 2u8)] {
                if let Some(t) = t {
                    if best.map_or(true, |b| (t, class) < b) {
                        best = Some((t, class));
                    }
                }
            }
            let Some((_, class)) = best else { break };
            if class == 0 {
                let (t, which) = ts.unwrap();
                now = now.max(t);
                vclock.store(now, Ordering::Relaxed);
                net.advance_to(t);
                match which {
                    0 => {
                        // The shard process dies: all in-memory state and
                        // every in-flight message addressed to it are gone.
                        let c = crash_pending.take().unwrap();
                        let idx = c.shard as usize;
                        shards[idx] = None;
                        down_shard = Some(idx);
                        crashes += 1;
                        let purged = net.purge_to(NodeId::Server(ShardId(c.shard)));
                        trace.push(format!("{t} crash shard{} purged={purged}", c.shard));
                    }
                    1 => {
                        // Respawn from checkpoint + WAL. `recover` bumps
                        // the durable epoch and announces itself to every
                        // client, which triggers their resync protocol.
                        restart_at = None;
                        let idx = down_shard.take().expect("restart without a dead shard");
                        let sh = ServerShard::recover(
                            ShardId(idx as u32),
                            cfg.procs,
                            registry.clone(),
                            sender.clone(),
                            spans.clone(),
                            shard_opts(idx),
                        )
                        .expect("recovery from in-memory persistence");
                        shards[idx] = Some(sh);
                        oracle.on_shard_restart(idx as u32);
                        if let Some(cm) = &coord_metrics {
                            cm.respawns.inc();
                        }
                        next_hb = None;
                        trace.push(format!("{t} restart shard{idx}"));
                    }
                    2 => {
                        // Failure detector: declare a shard dead after
                        // `heartbeat_deadline_us` of silence, then ping
                        // everyone again. Pings to the dead shard are
                        // dropped at delivery, like a failed connect.
                        for s in 0..cfg.shards as usize {
                            let silent = t.saturating_sub(last_pong[s]);
                            if silent > cfg.heartbeat_deadline_us && restart_at.is_none() {
                                if down_shard == Some(s) {
                                    let c = cfg.faults.crash.unwrap();
                                    restart_at = Some(t.max(c.at_us + c.restart_after_us));
                                    if let Some(cm) = &coord_metrics {
                                        cm.hb_misses.inc();
                                    }
                                    trace.push(format!("{t} detect shard{s} dead"));
                                } else if shards[s].is_some() {
                                    oracle.violate(
                                        t,
                                        "failure-detector",
                                        format!("live shard {s} declared dead after {silent}µs"),
                                    );
                                }
                            }
                        }
                        ping_seq += 1;
                        ping_sent_at.insert(ping_seq, t);
                        if ping_seq > 8 {
                            ping_sent_at.remove(&(ping_seq - 8));
                        }
                        for s in 0..cfg.shards {
                            let _ = sender.send(Msg {
                                src: NodeId::Coordinator,
                                dst: NodeId::Server(ShardId(s)),
                                payload: Payload::Ping { seq: ping_seq },
                            });
                        }
                        next_hb = Some(t + cfg.heartbeat_every_us.max(1));
                    }
                    _ => {
                        // Virtual-time eager flusher — the sim analogue of
                        // the production flusher threads, in proc order.
                        for core in &cores {
                            core.flush_eager_tables_limited(cfg.flush_max_rows);
                        }
                        next_flush = Some(t + cfg.flusher_every_us);
                    }
                }
            } else if class == 1 {
                let Some((at, msg)) = net.pop_next() else { continue };
                now = at;
                vclock.store(now, Ordering::Relaxed);
                if let NodeId::Server(s) = msg.dst {
                    if down_shard == Some(s.0 as usize) {
                        // Dead destination: the message is destroyed before
                        // the oracle sees it — it never happened.
                        dropped_to_dead += 1;
                        trace.push(format!(
                            "{at} drop {}->{} {} (shard down)",
                            msg.src,
                            msg.dst,
                            msg.payload.kind()
                        ));
                        continue;
                    }
                }
                oracle.observe_delivery(at, &msg);
                trace.push(format!(
                    "{at} net {}->{} {}",
                    msg.src,
                    msg.dst,
                    msg.payload.kind()
                ));
                match msg.dst {
                    NodeId::Server(s) => {
                        shards[s.0 as usize].as_mut().expect("delivery to dead shard").handle(msg);
                    }
                    NodeId::Client(p) => {
                        cores[p.0 as usize].handle_ingress(msg);
                    }
                    NodeId::Coordinator => {
                        if let Payload::Pong { shard, seq } = msg.payload {
                            last_pong[shard.0 as usize] = at;
                            if let (Some(cm), Some(&t0)) =
                                (&coord_metrics, ping_sent_at.get(&seq))
                            {
                                cm.hb_rtt_us.record(at.saturating_sub(t0));
                            }
                        }
                    }
                }
            } else {
                let Reverse((t, widx)) = heap.pop().unwrap();
                now = now.max(t);
                vclock.store(now, Ordering::Relaxed);
                net.advance_to(t);
                let w = &mut workers[widx];
                if w.cur.is_none() {
                    w.cur = w.plan_next(cfg);
                    if w.cur.is_none() {
                        w.done = true;
                        continue;
                    }
                }
                let complete = exec_op(cfg, &cores, w, &mut oracle, &mut trace, t);
                if complete {
                    ops_completed += 1;
                    w.finish_op();
                } else {
                    w.retries_cur += 1;
                    retries_total += 1;
                    match w.cur {
                        Some(Op::GetShared { .. } | Op::GetOwn | Op::FifoRead) => {
                            retries_read.inc();
                            blocked_read.add(w.cost_us);
                        }
                        _ => {
                            retries_write.inc();
                            blocked_write.add(w.cost_us);
                        }
                    }
                    if w.retries_cur > RETRY_CAP {
                        let detail = format!(
                            "worker {} stuck on {:?} after {RETRY_CAP} retries",
                            w.wid.0, w.cur
                        );
                        oracle.violate(t, "livelock", detail);
                        w.done = true;
                        continue;
                    }
                }
                if !w.done {
                    heap.push(Reverse((t + w.cost_us, widx)));
                }
            }
        }

        // If the run bailed out early (violation cap, step budget) while
        // the shard was still down, respawn it now: the drain needs a
        // full cluster to converge against.
        if let Some(idx) = down_shard {
            let sh = ServerShard::recover(
                ShardId(idx as u32),
                cfg.procs,
                registry.clone(),
                sender.clone(),
                spans.clone(),
                shard_opts(idx),
            )
            .expect("recovery from in-memory persistence");
            shards[idx] = Some(sh);
            oracle.on_shard_restart(idx as u32);
            if let Some(cm) = &coord_metrics {
                cm.respawns.inc();
            }
            trace.push(format!("{now} restart shard{idx} (forced at drain)"));
        }

        // Drain: flush leftovers (a livelock-killed worker may hold
        // egress), then run the network dry.
        for core in &cores {
            let _ = core.flush_all_tables();
        }
        trace.push(format!("{now} drain"));
        let mut drain_steps: u64 = 0;
        while let Some((at, msg)) = net.pop_next() {
            drain_steps += 1;
            if drain_steps > STEP_BUDGET {
                oracle.violate(at, "livelock", "drain did not quiesce".into());
                break;
            }
            now = at;
            vclock.store(now, Ordering::Relaxed);
            oracle.observe_delivery(at, &msg);
            trace.push(format!(
                "{at} net {}->{} {}",
                msg.src,
                msg.dst,
                msg.payload.kind()
            ));
            match msg.dst {
                NodeId::Server(s) => {
                    shards[s.0 as usize].as_mut().expect("delivery to dead shard").handle(msg);
                }
                NodeId::Client(p) => {
                    cores[p.0 as usize].handle_ingress(msg);
                }
                NodeId::Coordinator => {}
            }
        }

        let own_finals: Vec<(usize, u64, f32)> = workers
            .iter()
            .map(|w| (w.proc, cfg.own_row(w.wid.0), w.own_expected))
            .collect();
        oracle.check_quiescence(now, cfg, &desc, &cores, &shards, &own_finals);

        // Span-tree completeness: on crash-free schedules every accepted
        // batch must have a closed batch→net→apply→visible chain, and no
        // lifecycle span may reference a batch the wire never accepted.
        // A crash legitimately truncates chains (the respawned shard's
        // open-span maps are in-memory), and a saturated ring legitimately
        // loses spans — both are excluded, and the zero-drop expectation
        // is asserted separately by the CI trace slice.
        if cfg.faults.crash.is_none() && spans.dropped_spans() == 0 {
            let mut have: HashMap<u64, HashSet<(u32, u64)>> = HashMap::new();
            for (_, recs) in spans.spans() {
                for r in &recs {
                    if r.kind != SpanKind::Pull as u64 {
                        have.entry(r.kind).or_default().insert((r.b as u32, r.c));
                        if !oracle.accepted.contains(&(r.b as u32, r.c)) {
                            oracle.violate(
                                now,
                                "span-orphan",
                                format!(
                                    "kind {} span for origin {} batch {} never accepted",
                                    r.kind, r.b, r.c
                                ),
                            );
                        }
                    }
                }
            }
            let chain = [SpanKind::Batch, SpanKind::Net, SpanKind::Apply, SpanKind::Visible];
            for &(origin, batch_id) in &oracle.accepted {
                for kind in chain {
                    let ok = have
                        .get(&(kind as u64))
                        .is_some_and(|set| set.contains(&(origin, batch_id)));
                    if !ok {
                        oracle.violate(
                            now,
                            "span-chain",
                            format!(
                                "origin {origin} batch {batch_id}: no {} span",
                                kind.stage()
                            ),
                        );
                    }
                }
            }
        }

        SimReport {
            seed: cfg.seed,
            policy: cfg.policy.name(),
            trace_hash: trace.hash(),
            trace_lines: trace.len(),
            violations: oracle.violations.clone(),
            violations_truncated: oracle.truncated,
            net: net.stats(),
            ops_completed,
            retries: retries_total,
            crashes,
            dropped_to_dead,
            trace_tail: trace.tail(40),
            snapshot: hub.snapshot(),
            oracle_max_staleness: oracle.max_staleness,
            oracle_u_obs: oracle.u_obs,
            oracle_applied_batches: oracle.applied_batches,
            trace_json: keep_trace.then(|| spans.trace_json()),
        }
    }
}

/// Execute (or re-attempt) the worker's current op. Returns `true` when
/// the op completed; `false` means a gate held it and it will be retried.
fn exec_op(
    cfg: &SimConfig,
    cores: &[ClientCore],
    w: &mut SimWorker,
    oracle: &mut Oracle,
    trace: &mut SimTrace,
    at: u64,
) -> bool {
    let core = &cores[w.proc];
    let proc = w.proc as u32;
    let op = w.cur.expect("exec without a planned op");
    match op {
        Op::IncShared { row, col, delta } => {
            if cfg.sabotage == Sabotage::WriteGate {
                core.sabotage_inc(TABLE, RowId(row), col, delta).unwrap();
                oracle.record_write(at, proc, row, col, delta);
                trace.push(format!("{at} w{} sab_inc r{row}c{col} {delta:?}", w.wid.0));
                return true;
            }
            if core.try_inc(TABLE, RowId(row), col, delta).unwrap() {
                oracle.record_write(at, proc, row, col, delta);
                trace.push(format!("{at} w{} inc r{row}c{col} {delta:?}", w.wid.0));
                true
            } else {
                trace.push(format!("{at} w{} inc r{row}c{col} blocked", w.wid.0));
                false
            }
        }
        Op::GetShared { row, col } => {
            let rc = if cfg.sabotage == Sabotage::ReadGate { 0 } else { w.clock };
            match core.try_get(TABLE, RowId(row), col, rc).unwrap() {
                Some(v) => {
                    // Effective clock re-read in the same step: no
                    // deliveries can interleave, so it is exactly what
                    // the read observed.
                    let (_, snap_c, floor, _, _) = core.debug_param(TABLE, RowId(row), col);
                    oracle.check_staleness(at, w.wid, w.clock, row, snap_c.max(floor));
                    trace.push(format!("{at} w{} get r{row}c{col} -> {v:?}", w.wid.0));
                    true
                }
                None => {
                    trace.push(format!("{at} w{} get r{row}c{col} blocked", w.wid.0));
                    false
                }
            }
        }
        Op::IncOwn { delta } => {
            let row = cfg.own_row(w.wid.0);
            if core.try_inc(TABLE, RowId(row), col0(), delta).unwrap() {
                w.own_expected += delta;
                oracle.record_write(at, proc, row, col0(), delta);
                trace.push(format!("{at} w{} inc_own {delta:?}", w.wid.0));
                true
            } else {
                trace.push(format!("{at} w{} inc_own blocked", w.wid.0));
                false
            }
        }
        Op::GetOwn => {
            let row = cfg.own_row(w.wid.0);
            match core.try_get(TABLE, RowId(row), col0(), w.clock).unwrap() {
                Some(v) => {
                    // Mirror the staleness the client just recorded, so the
                    // oracle's max tracks every successful gated read.
                    let (_, snap_c, floor, _, _) = core.debug_param(TABLE, RowId(row), col0());
                    oracle.check_staleness(at, w.wid, w.clock, row, snap_c.max(floor));
                    if v != w.own_expected {
                        oracle.violate(
                            at,
                            "read-my-writes",
                            format!(
                                "worker {} read own row {row}: {v} != written {}",
                                w.wid.0, w.own_expected
                            ),
                        );
                    }
                    trace.push(format!("{at} w{} get_own -> {v:?}", w.wid.0));
                    true
                }
                None => {
                    trace.push(format!("{at} w{} get_own blocked", w.wid.0));
                    false
                }
            }
        }
        Op::FifoWrite => {
            let row = cfg.fifo_row();
            if w.fifo_stage == 0 {
                if !core.try_inc(TABLE, RowId(row), 0, 1.0).unwrap() {
                    trace.push(format!("{at} w{} fifo_w0 blocked", w.wid.0));
                    return false;
                }
                oracle.record_write(at, proc, row, 0, 1.0);
                w.fifo_stage = 1;
            }
            if !core.try_inc(TABLE, RowId(row), 1, 1.0).unwrap() {
                trace.push(format!("{at} w{} fifo_w1 blocked", w.wid.0));
                return false;
            }
            oracle.record_write(at, proc, row, 1, 1.0);
            trace.push(format!("{at} w{} fifo_w", w.wid.0));
            true
        }
        Op::FifoRead => {
            let row = cfg.fifo_row();
            // Both columns in one step ⇒ one consistent view: nothing can
            // be delivered between the two reads.
            let Some(v0) = core.try_get(TABLE, RowId(row), 0, w.clock).unwrap() else {
                trace.push(format!("{at} w{} fifo_r blocked", w.wid.0));
                return false;
            };
            let (_, c0, f0, _, _) = core.debug_param(TABLE, RowId(row), 0);
            oracle.check_staleness(at, w.wid, w.clock, row, c0.max(f0));
            let Some(v1) = core.try_get(TABLE, RowId(row), 1, w.clock).unwrap() else {
                trace.push(format!("{at} w{} fifo_r blocked", w.wid.0));
                return false;
            };
            let (_, c1, f1, _, _) = core.debug_param(TABLE, RowId(row), 1);
            oracle.check_staleness(at, w.wid, w.clock, row, c1.max(f1));
            if v0 < v1 {
                oracle.violate(
                    at,
                    "fifo",
                    format!(
                        "worker {} sees col1 sum {v1} ahead of col0 sum {v0}: some writer's \
                         second write overtook its first",
                        w.wid.0
                    ),
                );
            }
            trace.push(format!("{at} w{} fifo_r {v0:?}/{v1:?}", w.wid.0));
            true
        }
        Op::Tick => {
            let c = core.clock(w.wid).unwrap();
            w.clock += 1;
            if c != w.clock {
                oracle.violate(
                    at,
                    "clock-skew",
                    format!("worker {}: Clock() returned {c}, mirror {}", w.wid.0, w.clock),
                );
            }
            oracle.check_divergence(at, cfg, cores);
            trace.push(format!("{at} w{} clock {c}", w.wid.0));
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::FaultConfig;

    fn policies() -> Vec<PolicyConfig> {
        vec![
            PolicyConfig::Bsp,
            PolicyConfig::Ssp { staleness: 1 },
            PolicyConfig::Cap { staleness: 1 },
            PolicyConfig::Vap { v_thr: 2.0, strong: false },
            PolicyConfig::Vap { v_thr: 2.0, strong: true },
            PolicyConfig::Cvap { staleness: 2, v_thr: 2.0, strong: true },
        ]
    }

    #[test]
    fn same_seed_same_trace_every_policy() {
        for pol in policies() {
            let cfg = SimConfig::default().with_policy(pol).with_seed(7);
            let a = Sim::run(&cfg);
            let b = Sim::run(&cfg);
            assert_eq!(a.trace_hash, b.trace_hash, "{}: trace diverged", a.policy);
            assert_eq!(a.trace_lines, b.trace_lines, "{}: event count diverged", a.policy);
            assert!(a.ok(), "{}", a.describe());
        }
    }

    #[test]
    fn different_seeds_different_traces() {
        let a = Sim::run(&SimConfig::default().with_seed(1));
        let b = Sim::run(&SimConfig::default().with_seed(2));
        assert_ne!(a.trace_hash, b.trace_hash);
    }

    #[test]
    fn chaos_runs_uphold_all_bounds() {
        for pol in policies() {
            for seed in [11, 12, 13] {
                let r = Sim::run(&SimConfig::default().with_policy(pol).with_seed(seed));
                assert!(r.ok(), "{}", r.describe());
                assert!(r.ops_completed > 0);
            }
        }
    }

    #[test]
    fn straggler_run_is_clean() {
        let mut cfg = SimConfig::default()
            .with_policy(PolicyConfig::Ssp { staleness: 2 })
            .with_seed(5);
        cfg.stragglers = vec![(0, 8.0)];
        let r = Sim::run(&cfg);
        assert!(r.ok(), "{}", r.describe());
    }

    #[test]
    fn sabotaged_read_gate_is_caught() {
        // Bypassing the staleness gate (reads claim clock 0) under high
        // latency must surface stale reads to the oracle.
        let mut caught = false;
        for seed in 1..=8u64 {
            let mut cfg = SimConfig::default().with_policy(PolicyConfig::Bsp).with_seed(seed);
            cfg.sabotage = Sabotage::ReadGate;
            cfg.faults = FaultConfig { latency_us: 500, jitter_us: 200, ..FaultConfig::none() };
            cfg.op_cost_us = 10;
            let r = Sim::run(&cfg);
            if r.violations.iter().any(|v| v.kind == "staleness") {
                caught = true;
                break;
            }
        }
        assert!(caught, "read-gate sabotage never tripped the staleness oracle");
    }

    #[test]
    fn sabotaged_write_gate_is_caught() {
        let mut cfg = SimConfig::default()
            .with_policy(PolicyConfig::Vap { v_thr: 1.0, strong: false })
            .with_seed(3);
        cfg.sabotage = Sabotage::WriteGate;
        let r = Sim::run(&cfg);
        assert!(
            r.violations.iter().any(|v| v.kind == "value-bound"),
            "write-gate sabotage never tripped the value oracle: {}",
            r.describe()
        );
    }

    #[test]
    fn crash_recovery_run_upholds_all_bounds() {
        for pol in [
            PolicyConfig::Ssp { staleness: 1 },
            PolicyConfig::Vap { v_thr: 2.0, strong: false },
        ] {
            let cfg =
                SimConfig::default().with_policy(pol).with_seed(21).with_crash(0, 2_000, 3_000);
            let a = Sim::run(&cfg);
            let b = Sim::run(&cfg);
            assert_eq!(a.trace_hash, b.trace_hash, "{}: crash trace diverged", a.policy);
            assert_eq!(a.crashes, 1, "{}", a.describe());
            assert!(a.net.purged > 0 || a.dropped_to_dead > 0, "{}", a.describe());
            assert!(a.ok(), "{}", a.describe());
        }
    }

    #[test]
    fn traced_run_carries_tail() {
        let r = Sim::run_traced(&SimConfig::default().with_seed(9));
        assert!(!r.trace_tail.is_empty());
        assert!(r.ok(), "{}", r.describe());
    }
}
