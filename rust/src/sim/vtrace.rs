//! Virtual-time event trace with an incremental FNV-1a fingerprint.
//!
//! The harness appends one formatted line per event (delivery, op, clock
//! tick, violation). The 64-bit hash is updated incrementally so the
//! determinism check ("identical seed ⇒ byte-identical trace") is cheap
//! even when line storage is disabled; the sweep runs with storage off and
//! only failing seeds are re-run with storage on to print a tail.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Append-only event log: always hashes and counts, optionally stores.
pub struct SimTrace {
    hash: u64,
    lines: u64,
    keep: bool,
    entries: Vec<String>,
}

impl SimTrace {
    /// `keep = true` stores every line (debugging / failure reports);
    /// `false` only fingerprints.
    pub fn new(keep: bool) -> Self {
        SimTrace { hash: FNV_OFFSET, lines: 0, keep, entries: Vec::new() }
    }

    /// Append one event line (no trailing newline; one is hashed in).
    pub fn push(&mut self, line: String) {
        for b in line.as_bytes() {
            self.hash ^= *b as u64;
            self.hash = self.hash.wrapping_mul(FNV_PRIME);
        }
        self.hash ^= b'\n' as u64;
        self.hash = self.hash.wrapping_mul(FNV_PRIME);
        self.lines += 1;
        if self.keep {
            self.entries.push(line);
        }
    }

    /// Fingerprint over all lines so far.
    pub fn hash(&self) -> u64 {
        self.hash
    }

    /// Number of lines appended.
    pub fn len(&self) -> u64 {
        self.lines
    }

    /// True if nothing was appended.
    pub fn is_empty(&self) -> bool {
        self.lines == 0
    }

    /// Last `n` stored lines (empty when storage is off).
    pub fn tail(&self, n: usize) -> Vec<String> {
        let start = self.entries.len().saturating_sub(n);
        self.entries[start..].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_matches_reference_fnv() {
        // FNV-1a of "a\n" computed by hand: offset ^ 'a' * p ^ '\n' * p.
        let mut expect = FNV_OFFSET;
        expect ^= b'a' as u64;
        expect = expect.wrapping_mul(FNV_PRIME);
        expect ^= b'\n' as u64;
        expect = expect.wrapping_mul(FNV_PRIME);
        let mut t = SimTrace::new(false);
        t.push("a".to_string());
        assert_eq!(t.hash(), expect);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn storage_toggle_does_not_change_hash() {
        let mut a = SimTrace::new(false);
        let mut b = SimTrace::new(true);
        for s in ["x", "y", "zz"] {
            a.push(s.to_string());
            b.push(s.to_string());
        }
        assert_eq!(a.hash(), b.hash());
        assert!(a.tail(10).is_empty());
        assert_eq!(b.tail(2), vec!["y".to_string(), "zz".to_string()]);
    }

    #[test]
    fn line_split_is_not_ambiguous() {
        // "ab" + "c" must differ from "a" + "bc" (newline separator).
        let mut a = SimTrace::new(false);
        a.push("ab".into());
        a.push("c".into());
        let mut b = SimTrace::new(false);
        b.push("a".into());
        b.push("bc".into());
        assert_ne!(a.hash(), b.hash());
    }
}
