//! # BAPPS — Bounded-Asynchronous Parameter Server
//!
//! A from-scratch reproduction of *"Consistency Models for Distributed ML
//! with Theoretical Guarantees"* (Wei, Dai, Kumar, Zheng, Ho, Xing — CMU,
//! 2013), the paper behind Petuum PS. The library implements:
//!
//! * a **distributed parameter server**: hash-partitioned table shards,
//!   a client library with a two-level (process / thread) cache hierarchy,
//!   write-back op-logs, vector clocks, and batched, magnitude-prioritized
//!   update propagation ([`server`], [`client`], [`table`], [`comm`]);
//! * the paper's four **bounded-asynchronous consistency models** — SSP,
//!   CAP, VAP (weak & strong) and CVAP — expressed as pluggable
//!   [`consistency::ConsistencyPolicy`] values checked by a per-table
//!   consistency controller ([`consistency`]);
//! * **ML applications** exercising the server exactly the way the paper's
//!   evaluation does: collapsed-Gibbs LDA over a 20News-scale corpus,
//!   SGD logistic/linear regression (the Theorem-1 workload), matrix
//!   factorization, and a data-parallel transformer-LM driver ([`apps`]);
//! * a **PJRT runtime** that loads JAX/Pallas computations AOT-lowered to
//!   HLO text at build time, so Python is never on the worker path
//!   ([`runtime`]);
//! * a **deterministic simulation harness** that drives the real
//!   client/server/consistency stack over a seeded virtual-time network
//!   with injected faults (delay, reorder, duplicate, drop-with-retry,
//!   stragglers) and checks the paper's bounds as executable oracles
//!   ([`sim`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use bapps::prelude::*;
//!
//! let cfg = SystemConfig::builder()
//!     .num_server_shards(2)
//!     .num_client_procs(2)
//!     .threads_per_proc(2)
//!     .build();
//! let system = PsSystem::launch(cfg).unwrap();
//! let table = system.create_table(TableDesc {
//!     id: TableId(0),
//!     num_rows: 16,
//!     row_width: 8,
//!     row_kind: RowKind::Dense,
//!     policy: PolicyConfig::Ssp { staleness: 2 },
//! }).unwrap();
//! system.run_workers(move |ctx| {
//!     let t = ctx.table(TableId(0));
//!     for _clock in 0..10 {
//!         t.inc(RowId(ctx.worker_id().0 as u64 % 16), 0, 1.0).unwrap();
//!         ctx.clock();
//!     }
//! }).unwrap();
//! system.shutdown().unwrap();
//! ```

pub mod apps;
pub mod client;
pub mod clock;
pub mod comm;
pub mod config;
pub mod consistency;
pub mod coordinator;
pub mod error;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod table;
pub mod trace;
pub mod util;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::client::{TableHandle, WorkerCtx};
    pub use crate::clock::VectorClock;
    pub use crate::config::{NetConfig, PolicyConfig, SystemConfig, SystemConfigBuilder};
    pub use crate::consistency::ConsistencyModel;
    pub use crate::coordinator::PsSystem;
    pub use crate::error::{Error, Result};
    pub use crate::table::{RowId, RowKind, TableDesc, TableId};
    pub use crate::types::{ProcId, ShardId, WorkerId};
}

/// Small shared identifier types used across every layer.
pub mod types {
    /// A client *process* (the paper's "application process"). Each process
    /// hosts several worker threads and one shared process cache.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct ProcId(pub u32);

    /// A server shard process. Tables are hash-partitioned over shards with
    /// the row as the unit of distribution (paper §4.1).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct ShardId(pub u32);

    /// A worker *thread* — the unit the consistency models call a "worker".
    /// Globally unique across processes: `WorkerId = proc * threads + local`.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct WorkerId(pub u32);

    /// Logical clock value ("iteration"): starts at 0, incremented by
    /// `Clock()`. Updates generated in `(c-1, c]` are timestamped `c`.
    pub type Clock = u32;

    /// Monotone per-worker update sequence number (for FIFO + visibility
    /// tracking, cf. Figure 1's `(seq, value)` pairs).
    pub type UpdateSeq = u64;

    /// Any endpoint on the simulated network.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub enum NodeId {
        /// A client process endpoint.
        Client(ProcId),
        /// A server shard endpoint.
        Server(ShardId),
        /// The coordinator/name-node endpoint (table creation, barriers).
        Coordinator,
    }

    impl std::fmt::Display for NodeId {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            match self {
                NodeId::Client(p) => write!(f, "client{}", p.0),
                NodeId::Server(s) => write!(f, "server{}", s.0),
                NodeId::Coordinator => write!(f, "coord"),
            }
        }
    }
}
