//! Vector clocks (paper §4.2).
//!
//! Progress of a worker thread is an integer *clock*; a client process's
//! progress is the **minimum** over its threads' clocks, and the global
//! progress the server reasons about is the minimum over process clocks.
//! The paper tracks this with a two-level vector-clock scheme: each client
//! library keeps a vector clock over its threads, and each server keeps a
//! vector clock over client processes. [`VectorClock`] implements both
//! levels; it is generic over the entity id.

use std::collections::BTreeMap;
use std::hash::Hash;

use crate::types::Clock;

/// A vector clock over a fixed set of entities (threads or processes).
///
/// Entities are registered up front; [`VectorClock::tick`] advances one
/// entity, and [`VectorClock::min_clock`] gives the frontier used by the
/// clock-bounded consistency models. The structure also reports *when the
/// minimum advances*, which is the event that unblocks CAP/SSP waiters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VectorClock<K: Ord + Eq + Hash + Copy> {
    clocks: BTreeMap<K, Clock>,
    /// Cached minimum over `clocks` (recomputed on tick when the ticking
    /// entity was at the minimum).
    min: Clock,
}

impl<K: Ord + Eq + Hash + Copy> VectorClock<K> {
    /// Create a vector clock with every entity at clock 0.
    pub fn new(entities: impl IntoIterator<Item = K>) -> Self {
        let clocks: BTreeMap<K, Clock> = entities.into_iter().map(|e| (e, 0)).collect();
        VectorClock { clocks, min: 0 }
    }

    /// Create an empty vector clock; entities may be added with
    /// [`VectorClock::register`].
    pub fn empty() -> Self {
        VectorClock { clocks: BTreeMap::new(), min: 0 }
    }

    /// Register a new entity at clock 0 (or at `at` if provided later
    /// entities join a warm system). Returns `false` if already present.
    pub fn register(&mut self, entity: K) -> bool {
        if self.clocks.contains_key(&entity) {
            return false;
        }
        self.clocks.insert(entity, 0);
        self.min = 0;
        true
    }

    /// Number of tracked entities.
    pub fn len(&self) -> usize {
        self.clocks.len()
    }

    /// True when no entity is registered.
    pub fn is_empty(&self) -> bool {
        self.clocks.is_empty()
    }

    /// The clock of one entity (None if unregistered).
    pub fn get(&self, entity: K) -> Option<Clock> {
        self.clocks.get(&entity).copied()
    }

    /// Advance `entity` by one. Returns `Some(new_min)` if the *minimum*
    /// advanced (the event CAP/SSP waiters care about), else `None`.
    ///
    /// Panics if the entity is unregistered — that is always a topology
    /// bug, not a runtime condition.
    pub fn tick(&mut self, entity: K) -> Option<Clock> {
        let c = self
            .clocks
            .get_mut(&entity)
            .unwrap_or_else(|| panic!("tick on unregistered vector-clock entity"));
        let was = *c;
        *c = was + 1;
        if was == self.min {
            let new_min = self.clocks.values().copied().min().unwrap_or(0);
            if new_min > self.min {
                self.min = new_min;
                return Some(new_min);
            }
        }
        None
    }

    /// Set `entity` to `clock` (used by servers applying client clock
    /// notifications, which may batch several ticks). Clocks never move
    /// backwards; a stale notification is ignored. Returns `Some(new_min)`
    /// when the minimum advanced.
    pub fn advance_to(&mut self, entity: K, clock: Clock) -> Option<Clock> {
        let c = self
            .clocks
            .get_mut(&entity)
            .unwrap_or_else(|| panic!("advance_to on unregistered vector-clock entity"));
        if clock <= *c {
            return None;
        }
        let was = *c;
        *c = clock;
        if was == self.min {
            let new_min = self.clocks.values().copied().min().unwrap_or(0);
            if new_min > self.min {
                self.min = new_min;
                return Some(new_min);
            }
        }
        None
    }

    /// The minimum clock over all entities — "the progress of the process"
    /// (client-side) or of the whole system (server-side).
    pub fn min_clock(&self) -> Clock {
        self.min
    }

    /// The maximum clock over all entities (the fastest worker).
    pub fn max_clock(&self) -> Clock {
        self.clocks.values().copied().max().unwrap_or(0)
    }

    /// Spread between the fastest and the slowest entity — the quantity the
    /// clock-bounded models keep `≤ s`.
    pub fn skew(&self) -> Clock {
        self.max_clock() - self.min
    }

    /// Iterate `(entity, clock)` pairs in entity order.
    pub fn iter(&self) -> impl Iterator<Item = (K, Clock)> + '_ {
        self.clocks.iter().map(|(k, v)| (*k, *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_advances_only_when_slowest_moves() {
        let mut vc = VectorClock::new([0u32, 1, 2]);
        assert_eq!(vc.min_clock(), 0);
        assert_eq!(vc.tick(0), None); // 1,0,0
        assert_eq!(vc.tick(1), None); // 1,1,0
        assert_eq!(vc.tick(2), Some(1)); // 1,1,1 -> min advanced
        assert_eq!(vc.min_clock(), 1);
        assert_eq!(vc.skew(), 0);
    }

    #[test]
    fn skew_tracks_fast_minus_slow() {
        let mut vc = VectorClock::new([0u32, 1]);
        for _ in 0..5 {
            vc.tick(0);
        }
        assert_eq!(vc.skew(), 5);
        assert_eq!(vc.max_clock(), 5);
        assert_eq!(vc.min_clock(), 0);
    }

    #[test]
    fn advance_to_ignores_stale_and_batches() {
        let mut vc = VectorClock::new([10u32, 20]);
        assert_eq!(vc.advance_to(10, 3), None); // 3,0
        assert_eq!(vc.advance_to(20, 2), Some(2)); // 3,2 -> min moved 0->2
        assert_eq!(vc.advance_to(20, 1), None); // stale, ignored
        assert_eq!(vc.get(20), Some(2));
        assert_eq!(vc.min_clock(), 2);
    }

    #[test]
    fn register_resets_min() {
        let mut vc = VectorClock::new([0u32]);
        vc.tick(0);
        vc.tick(0);
        assert_eq!(vc.min_clock(), 2);
        assert!(vc.register(1)); // new entity at 0 drags min down
        assert_eq!(vc.min_clock(), 0);
        assert!(!vc.register(1));
    }

    #[test]
    #[should_panic(expected = "unregistered")]
    fn tick_unregistered_panics() {
        let mut vc: VectorClock<u32> = VectorClock::empty();
        vc.tick(7);
    }

    #[test]
    fn empty_clock_mins_are_zero() {
        let vc: VectorClock<u32> = VectorClock::empty();
        assert_eq!(vc.min_clock(), 0);
        assert_eq!(vc.max_clock(), 0);
        assert!(vc.is_empty());
    }
}
