//! The compute-service pool: thread-safe façade over thread-confined
//! PJRT engines.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

use super::engine::Engine;
use super::Tensor;

enum Job {
    /// Execute one artifact and reply with its outputs.
    Run { name: String, inputs: Vec<Tensor>, reply: SyncSender<Result<Vec<Tensor>>> },
    /// Compile `names` into this thread's engine cache, then rendezvous at
    /// `barrier` so no thread can dequeue a second warm job before every
    /// thread holds one (the barrier is what makes warmup cover *all*
    /// threads rather than however many were idle).
    Warm { names: Arc<Vec<String>>, barrier: Arc<Barrier>, reply: SyncSender<usize> },
}

/// A pool of PJRT service threads. Clone-free sharing via `Arc`.
///
/// ```no_run
/// use bapps::runtime::{ComputePool, Tensor};
/// let pool = ComputePool::start("artifacts", 1).unwrap();
/// let grad = pool.run("logreg_grad", vec![Tensor::zeros(vec![8, 4])]).unwrap();
/// ```
pub struct ComputePool {
    tx: Sender<Job>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes [`ComputePool::warmup`] calls: two concurrent warmups
    /// would split the threads across two barriers and deadlock.
    warmup_lock: Mutex<()>,
}

impl ComputePool {
    /// Start `num_threads` service threads, each with its own PJRT CPU
    /// client rooted at `artifacts_dir`. Artifacts compile lazily, once
    /// per thread, on first use.
    pub fn start(artifacts_dir: impl Into<PathBuf>, num_threads: usize) -> Result<Self> {
        let dir = artifacts_dir.into();
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for i in 0..num_threads.max(1) {
            let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
            let dir = dir.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt{i}"))
                    .spawn(move || {
                        // Engine construction failure is reported per job.
                        let mut engine: Option<Engine> = None;
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                match guard.recv() {
                                    Ok(j) => j,
                                    Err(_) => break,
                                }
                            };
                            match job {
                                Job::Run { name, inputs, reply } => {
                                    let result = (|| {
                                        if engine.is_none() {
                                            engine = Some(Engine::cpu(dir.clone())?);
                                        }
                                        let eng = engine.as_mut().unwrap();
                                        let comp = eng.load(&name)?;
                                        comp.run_f32(&inputs)
                                    })();
                                    let _ = reply.send(result);
                                }
                                Job::Warm { names, barrier, reply } => {
                                    // Best-effort: a missing artifact or a
                                    // failed engine warms nothing but must
                                    // still hit the barrier, or the other
                                    // threads' warm jobs hang.
                                    let warmed = (|| {
                                        if engine.is_none() {
                                            match Engine::cpu(dir.clone()) {
                                                Ok(e) => engine = Some(e),
                                                Err(_) => return 0,
                                            }
                                        }
                                        let eng = engine.as_mut().unwrap();
                                        names.iter().filter(|n| eng.load(n).is_ok()).count()
                                    })();
                                    barrier.wait();
                                    let _ = reply.send(warmed);
                                }
                            }
                        }
                    })
                    .map_err(Error::Io)?,
            );
        }
        Ok(ComputePool { tx, handles, warmup_lock: Mutex::new(()) })
    }

    /// Execute artifact `name` with `inputs`; blocks until the result is
    /// ready. Safe to call from any number of threads concurrently.
    pub fn run(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Job::Run { name: name.to_string(), inputs, reply: reply_tx })
            .map_err(|_| Error::Runtime("compute pool stopped".into()))?;
        reply_rx.recv().map_err(|_| Error::Runtime("compute pool dropped job".into()))?
    }

    /// Warm the caches: compile `names` on **every** service thread so the
    /// first hot-path call doesn't pay compilation. One warm job per
    /// thread, with a barrier keeping any thread from taking two, so
    /// coverage is exact rather than "whoever was idle". Best-effort per
    /// artifact (missing ones are skipped); blocks until all threads are
    /// done and returns the total number of successful loads.
    pub fn warmup(&self, names: &[&str]) -> usize {
        let _serial = self.warmup_lock.lock().unwrap();
        let n = self.handles.len();
        let names: Arc<Vec<String>> = Arc::new(names.iter().map(|s| s.to_string()).collect());
        let barrier = Arc::new(Barrier::new(n));
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(n);
        for _ in 0..n {
            let job = Job::Warm {
                names: Arc::clone(&names),
                barrier: Arc::clone(&barrier),
                reply: reply_tx.clone(),
            };
            if self.tx.send(job).is_err() {
                return 0; // pool stopped
            }
        }
        drop(reply_tx);
        reply_rx.iter().sum()
    }

    /// Stop the pool and join service threads.
    pub fn shutdown(mut self) {
        drop(self.tx);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_error_propagates() {
        let pool = ComputePool::start("/nope", 1).unwrap();
        let err = pool.run("missing", vec![]).unwrap_err();
        assert!(matches!(err, Error::MissingArtifact(_)), "{err}");
        pool.shutdown();
    }

    #[test]
    fn warmup_covers_all_threads_and_tolerates_missing_artifacts() {
        // No artifacts exist under /nope: every load fails, so the total is
        // 0 — but the call must complete (barrier reached on all threads)
        // and the pool must stay usable afterwards.
        let pool = ComputePool::start("/nope", 3).unwrap();
        assert_eq!(pool.warmup(&["logreg_grad", "missing"]), 0);
        assert_eq!(pool.warmup(&[]), 0);
        assert!(pool.run("missing", vec![]).is_err());
        pool.shutdown();
    }

    #[test]
    fn pool_survives_many_concurrent_error_jobs() {
        let pool = std::sync::Arc::new(ComputePool::start("/nope", 2).unwrap());
        let mut joins = Vec::new();
        for _ in 0..8 {
            let p = pool.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    assert!(p.run("missing", vec![]).is_err());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
