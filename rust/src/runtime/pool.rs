//! The compute-service pool: thread-safe façade over thread-confined
//! PJRT engines.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

use super::engine::Engine;
use super::Tensor;

struct Job {
    name: String,
    inputs: Vec<Tensor>,
    reply: SyncSender<Result<Vec<Tensor>>>,
}

/// A pool of PJRT service threads. Clone-free sharing via `Arc`.
///
/// ```no_run
/// use bapps::runtime::{ComputePool, Tensor};
/// let pool = ComputePool::start("artifacts", 1).unwrap();
/// let grad = pool.run("logreg_grad", vec![Tensor::zeros(vec![8, 4])]).unwrap();
/// ```
pub struct ComputePool {
    tx: Sender<Job>,
    handles: Vec<JoinHandle<()>>,
}

impl ComputePool {
    /// Start `num_threads` service threads, each with its own PJRT CPU
    /// client rooted at `artifacts_dir`. Artifacts compile lazily, once
    /// per thread, on first use.
    pub fn start(artifacts_dir: impl Into<PathBuf>, num_threads: usize) -> Result<Self> {
        let dir = artifacts_dir.into();
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for i in 0..num_threads.max(1) {
            let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
            let dir = dir.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt{i}"))
                    .spawn(move || {
                        // Engine construction failure is reported per job.
                        let mut engine: Option<Engine> = None;
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                match guard.recv() {
                                    Ok(j) => j,
                                    Err(_) => break,
                                }
                            };
                            let result = (|| {
                                if engine.is_none() {
                                    engine = Some(Engine::cpu(dir.clone())?);
                                }
                                let eng = engine.as_mut().unwrap();
                                let comp = eng.load(&job.name)?;
                                comp.run_f32(&job.inputs)
                            })();
                            let _ = job.reply.send(result);
                        }
                    })
                    .map_err(Error::Io)?,
            );
        }
        Ok(ComputePool { tx, handles })
    }

    /// Execute artifact `name` with `inputs`; blocks until the result is
    /// ready. Safe to call from any number of threads concurrently.
    pub fn run(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Job { name: name.to_string(), inputs, reply: reply_tx })
            .map_err(|_| Error::Runtime("compute pool stopped".into()))?;
        reply_rx.recv().map_err(|_| Error::Runtime("compute pool dropped job".into()))?
    }

    /// Warm the caches: compile `names` on every service thread so the
    /// first hot-path call doesn't pay compilation. Best-effort.
    pub fn warmup(&self, names: &[&str]) {
        // A run with empty inputs will fail execution but still compile;
        // instead we just issue a real load via a zero-input probe only
        // when the artifact takes zero inputs. Simplest robust warmup:
        // callers run one real step; this helper is a no-op placeholder
        // kept for API stability.
        let _ = names;
    }

    /// Stop the pool and join service threads.
    pub fn shutdown(mut self) {
        drop(self.tx);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_error_propagates() {
        let pool = ComputePool::start("/nope", 1).unwrap();
        let err = pool.run("missing", vec![]).unwrap_err();
        assert!(matches!(err, Error::MissingArtifact(_)), "{err}");
        pool.shutdown();
    }

    #[test]
    fn pool_survives_many_concurrent_error_jobs() {
        let pool = std::sync::Arc::new(ComputePool::start("/nope", 2).unwrap());
        let mut joins = Vec::new();
        for _ in 0..8 {
            let p = pool.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    assert!(p.run("missing", vec![]).is_err());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
