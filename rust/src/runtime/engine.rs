//! Single-threaded PJRT engine (owned by one [`super::ComputePool`]
//! service thread; `PjRtClient` is `Rc`-based and must not cross threads).
//!
//! Two backends share one API surface:
//!
//! * with `--features xla` the real PJRT backend loads AOT-lowered HLO
//!   text and executes it;
//! * without it (the default — the offline build has no `xla` crate) a
//!   std-only stub stands in. The stub preserves the *error contract*:
//!   missing artifact files still surface as [`Error::MissingArtifact`]
//!   (so `make artifacts` hints keep working and artifact-gated tests
//!   self-skip exactly as before), and anything that would need a real
//!   compiler reports [`Error::Runtime`] instead of wrong numbers.

#[cfg(feature = "xla")]
pub use real::{Computation, Engine};
#[cfg(not(feature = "xla"))]
pub use stub::{Computation, Engine};

#[cfg(feature = "xla")]
mod real {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    use crate::error::{Error, Result};
    use crate::runtime::Tensor;

    /// A compiled executable (thread-confined).
    pub struct Computation {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl Computation {
        /// Artifact name (file stem).
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with f32 tensor inputs; returns the flattened tuple of f32
        /// outputs. The artifact must have been lowered with
        /// `return_tuple=True` (our `aot.py` always does).
        pub fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for t in inputs {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.name)))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("to_literal {}: {e}", self.name)))?;
            let parts = out
                .to_tuple()
                .map_err(|e| Error::Runtime(format!("tuple decompose {}: {e}", self.name)))?;
            let mut tensors = Vec::with_capacity(parts.len());
            for p in parts {
                let shape = p
                    .array_shape()
                    .map_err(|e| Error::Runtime(format!("output shape {}: {e}", self.name)))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = p
                    .to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("output data {}: {e}", self.name)))?;
                tensors.push(Tensor { data, shape: dims });
            }
            Ok(tensors)
        }
    }

    /// One PJRT CPU client + a cache of compiled artifacts.
    pub struct Engine {
        client: xla::PjRtClient,
        cache: HashMap<String, Rc<Computation>>,
        artifacts_dir: PathBuf,
    }

    impl Engine {
        /// Create a CPU engine rooted at `artifacts_dir`.
        pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu: {e}")))?;
            Ok(Engine { client, cache: HashMap::new(), artifacts_dir: artifacts_dir.into() })
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile `artifacts_dir/<name>.hlo.txt` (cached).
        pub fn load(&mut self, name: &str) -> Result<Rc<Computation>> {
            if let Some(c) = self.cache.get(name) {
                return Ok(c.clone());
            }
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            let comp = self.load_path(name, &path)?;
            self.cache.insert(name.to_string(), comp.clone());
            Ok(comp)
        }

        /// Load and compile an explicit HLO-text path (uncached).
        pub fn load_path(&self, name: &str, path: &Path) -> Result<Rc<Computation>> {
            if !path.exists() {
                return Err(Error::MissingArtifact(path.to_path_buf()));
            }
            let path_str = path
                .to_str()
                .ok_or_else(|| Error::Runtime(format!("non-utf8 path {}", path.display())))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", path.display())))?;
            Ok(Rc::new(Computation { exe, name: name.to_string() }))
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    use crate::error::{Error, Result};
    use crate::runtime::Tensor;

    /// Stand-in for a compiled executable; executing it is an error.
    pub struct Computation {
        name: String,
    }

    impl Computation {
        /// Artifact name (file stem).
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Always fails: the stub cannot execute HLO.
        pub fn run_f32(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(Error::Runtime(format!(
                "cannot execute '{}': bapps was built without the `xla` feature",
                self.name
            )))
        }
    }

    /// Artifact-path bookkeeping without a PJRT client.
    pub struct Engine {
        cache: HashMap<String, Rc<Computation>>,
        artifacts_dir: PathBuf,
    }

    impl Engine {
        /// Create a stub engine rooted at `artifacts_dir` (always succeeds).
        pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            Ok(Engine { cache: HashMap::new(), artifacts_dir: artifacts_dir.into() })
        }

        /// Backend name (diagnostics).
        pub fn platform(&self) -> String {
            "cpu-stub (xla feature disabled)".to_string()
        }

        /// Resolve `artifacts_dir/<name>.hlo.txt`; missing files report
        /// [`Error::MissingArtifact`], present ones [`Error::Runtime`]
        /// (the stub has no compiler).
        pub fn load(&mut self, name: &str) -> Result<Rc<Computation>> {
            if let Some(c) = self.cache.get(name) {
                return Ok(c.clone());
            }
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            let comp = self.load_path(name, &path)?;
            self.cache.insert(name.to_string(), comp.clone());
            Ok(comp)
        }

        /// Check an explicit HLO-text path; see [`Engine::load`].
        pub fn load_path(&self, name: &str, path: &Path) -> Result<Rc<Computation>> {
            if !path.exists() {
                return Err(Error::MissingArtifact(path.to_path_buf()));
            }
            let _ = name;
            Err(Error::Runtime(format!(
                "cannot compile {}: bapps was built without the `xla` feature",
                path.display()
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn missing_artifact_is_reported() {
        let mut eng = Engine::cpu("/definitely/not/here").unwrap();
        match eng.load("nope") {
            Err(Error::MissingArtifact(p)) => {
                assert!(p.to_string_lossy().contains("nope.hlo.txt"))
            }
            other => panic!("expected MissingArtifact, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn engine_reports_platform() {
        let eng = Engine::cpu("artifacts").unwrap();
        assert!(!eng.platform().is_empty());
    }
}
