//! PJRT runtime: load JAX/Pallas computations AOT-lowered to HLO text and
//! execute them from Rust.
//!
//! Build-time Python (`python/compile/aot.py`) lowers each L2 jax function
//! — with its L1 Pallas kernels inlined (interpret mode) — to **HLO text**
//! in `artifacts/<name>.hlo.txt`. The Rust side loads and compiles each
//! artifact once; workers execute on the hot path with zero Python.
//!
//! HLO *text* (not a serialized `HloModuleProto`) is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the
//! crate's pinned XLA rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`).
//!
//! ### Threading
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so PJRT
//! objects must stay on their owning thread. [`ComputePool`] therefore
//! runs `num_threads` service threads, each owning its own client and
//! per-thread compiled-artifact cache; worker threads submit jobs over a
//! channel and block on the reply. XLA's CPU backend parallelizes inside
//! one execution, so a small pool (1–2) is usually right.

mod engine;
mod pool;

pub use engine::{Computation, Engine};
pub use pool::ComputePool;

use crate::error::{Error, Result};

/// An f32 tensor argument/result: flat row-major data + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Row-major values.
    pub data: Vec<f32>,
    /// Dimensions.
    pub shape: Vec<usize>,
}

impl Tensor {
    /// Build a tensor, validating that the shape covers the data.
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Runtime(format!(
                "shape {shape:?} ({n} elems) does not match data len {}",
                data.len()
            )));
        }
        Ok(Tensor { data, shape })
    }

    /// A zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { data: vec![0.0; n], shape }
    }

    /// A scalar tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { data: vec![v], shape: vec![] }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The single value of a scalar/1-element tensor.
    pub fn item(&self) -> Result<f32> {
        if self.data.len() == 1 {
            Ok(self.data[0])
        } else {
            Err(Error::Runtime(format!("item() on tensor of {} elems", self.data.len())))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![0.0; 6], vec![2, 3]).is_ok());
        assert!(Tensor::new(vec![0.0; 5], vec![2, 3]).is_err());
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.len(), 16);
        assert!(!z.is_empty());
    }

    #[test]
    fn scalar_and_item() {
        let s = Tensor::scalar(3.5);
        assert_eq!(s.item().unwrap(), 3.5);
        assert!(Tensor::zeros(vec![2]).item().is_err());
    }
}
