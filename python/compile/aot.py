"""AOT lowering: jax (L2, with L1 Pallas inlined) → HLO text artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. Python never runs on the worker path.

HLO *text* (not ``lowered.compile().serialize()``) is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the
pinned xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md and DESIGN.md).

Artifacts:
  artifacts/logreg_grad.hlo.txt       (B=128, D=64 baked; sum-reduced)
  artifacts/lda_topic_probs.hlo.txt   (B=128, K from --topics)
  artifacts/transformer_step.hlo.txt  (dims from --preset)
  artifacts/transformer_meta.txt      (PS-table layout contract)

Usage: python -m compile.aot --out-dir ../artifacts [--preset small|medium]
                             [--topics 128] [--logreg-d 64]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def lower_logreg(out_dir: str, batch: int, d: int) -> None:
    spec_w = jax.ShapeDtypeStruct((d,), jnp.float32)
    spec_x = jax.ShapeDtypeStruct((batch, d), jnp.float32)
    spec_y = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(model.logreg_grad).lower(spec_w, spec_x, spec_y)
    write(os.path.join(out_dir, "logreg_grad.hlo.txt"), to_hlo_text(lowered))
    write(
        os.path.join(out_dir, "logreg_meta.txt"),
        f"batch {batch}\nd {d}\n",
    )


def lower_lda(out_dir: str, batch: int, topics: int) -> None:
    spec_nwk = jax.ShapeDtypeStruct((batch, topics), jnp.float32)
    spec_k = jax.ShapeDtypeStruct((topics,), jnp.float32)
    spec_s = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(model.lda_topic_probs).lower(
        spec_nwk, spec_k, spec_k, spec_s, spec_s, spec_s
    )
    write(os.path.join(out_dir, "lda_topic_probs.hlo.txt"), to_hlo_text(lowered))
    write(
        os.path.join(out_dir, "lda_meta.txt"),
        f"batch {batch}\ntopics {topics}\n",
    )


PRESETS = {
    # vocab, d_model, n_layers, n_heads, seq_len, batch
    "tiny": model.TransformerConfig(256, 64, 1, 2, 32, 4),
    "small": model.TransformerConfig(512, 128, 2, 4, 64, 8),
    "medium": model.TransformerConfig(2048, 256, 4, 8, 128, 8),
}


def lower_transformer(out_dir: str, preset: str) -> None:
    cfg = PRESETS[preset]
    step, spec = model.make_transformer_step(cfg)
    arg_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    arg_specs.append(
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.float32)
    )
    lowered = jax.jit(step).lower(*arg_specs)
    write(os.path.join(out_dir, "transformer_step.hlo.txt"), to_hlo_text(lowered))

    meta = [
        f"vocab {cfg.vocab}",
        f"d_model {cfg.d_model}",
        f"n_layers {cfg.n_layers}",
        f"n_heads {cfg.n_heads}",
        f"seq_len {cfg.seq_len}",
        f"batch {cfg.batch}",
    ]
    for name, shape in spec:
        meta.append("param " + name + " " + " ".join(str(x) for x in shape))
    write(os.path.join(out_dir, "transformer_meta.txt"), "\n".join(meta) + "\n")
    n = sum(int(jnp.prod(jnp.array(s))) for _, s in spec)
    print(f"transformer preset '{preset}': {n:,} parameters")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--topics", type=int, default=128,
                    help="K baked into the LDA artifact (lane-aligned)")
    ap.add_argument("--lda-batch", type=int, default=128)
    ap.add_argument("--logreg-batch", type=int, default=128)
    ap.add_argument("--logreg-d", type=int, default=64)
    ap.add_argument("--only", choices=["logreg", "lda", "transformer"],
                    help="lower a single artifact")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    if args.only in (None, "logreg"):
        lower_logreg(args.out_dir, args.logreg_batch, args.logreg_d)
    if args.only in (None, "lda"):
        lower_lda(args.out_dir, args.lda_batch, args.topics)
    if args.only in (None, "transformer"):
        lower_transformer(args.out_dir, args.preset)


if __name__ == "__main__":
    main()
