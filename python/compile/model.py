"""L2: the jax compute graphs the Rust workers execute via PJRT.

Three entry points, each AOT-lowered to HLO text by :mod:`compile.aot`:

* :func:`logreg_grad` — the Theorem-1 SGD workload's fused gradient
  (wraps the L1 kernel :mod:`compile.kernels.logreg`);
* :func:`lda_topic_probs` — batched Gibbs topic probabilities (wraps
  :mod:`compile.kernels.lda`);
* :func:`make_transformer_step` — full fwd+bwd of a small decoder-only
  transformer LM whose matmuls all route through the L1 tiled kernel
  :func:`compile.kernels.matmul.pmatmul` (custom VJP, so the backward
  matmuls are Pallas too).

Everything is f32 and shape-static (HLO has no dynamic shapes): batch
sizes are baked by ``aot.py`` and the Rust side pads to them.
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import lda as lda_kernel
from compile.kernels import logreg as logreg_kernel
from compile.kernels.matmul import pmatmul


# --------------------------------------------------------------------------
# SGD logistic regression (Theorem 1 workload)
# --------------------------------------------------------------------------


def logreg_grad(w, x, y):
    """Sum-gradient + sum-loss for a logistic-regression minibatch.

    Returns ``(grad_sum [D], loss_sum [1])``; the Rust caller divides by
    the true (un-padded) batch size.
    """
    grad, loss = logreg_kernel.logreg_grad_sum(w, x, y)
    return grad, loss


# --------------------------------------------------------------------------
# LDA topic probabilities
# --------------------------------------------------------------------------


def lda_topic_probs(n_wk, n_dk, n_k, alpha, beta, vbeta):
    """Batched unnormalized Gibbs topic probabilities ``[B, K]``."""
    return (lda_kernel.lda_topic_probs(n_wk, n_dk, n_k, alpha, beta, vbeta),)


# --------------------------------------------------------------------------
# Transformer LM (end-to-end validation workload, DESIGN.md E8)
# --------------------------------------------------------------------------


class TransformerConfig:
    """Static model dimensions (baked into the artifact)."""

    def __init__(self, vocab=512, d_model=128, n_layers=2, n_heads=4, seq_len=64, batch=8):
        assert d_model % n_heads == 0
        # MXU-friendly dims: the Pallas matmul tiles are min(128, dim), so
        # any power-of-two ≥ 32 keeps the grid exact.
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.seq_len = seq_len
        self.batch = batch

    def param_spec(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list — the PS-table layout contract with
        Rust (`transformer_meta.txt`)."""
        d, v, s = self.d_model, self.vocab, self.seq_len
        spec = [("embed", (v, d)), ("pos", (s, d))]
        for i in range(self.n_layers):
            spec += [
                (f"L{i}.wq", (d, d)),
                (f"L{i}.wk", (d, d)),
                (f"L{i}.wv", (d, d)),
                (f"L{i}.wo", (d, d)),
                (f"L{i}.w1", (d, 4 * d)),
                (f"L{i}.w2", (4 * d, d)),
                (f"L{i}.ln1_scale", (d,)),
                (f"L{i}.ln1_bias", (d,)),
                (f"L{i}.ln2_scale", (d,)),
                (f"L{i}.ln2_bias", (d,)),
            ]
        spec += [("ln_f_scale", (d,)), ("ln_f_bias", (d,)), ("unembed", (d, v))]
        return spec


def _layernorm(x, scale, bias):
    """LN with the (1 + scale) parametrization so zero-initialized PS
    tables start at identity scale (see rust `init_std`)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    return xn * (1.0 + scale) + bias


def _mm(x, w):
    """Route a (possibly >2-D) matmul through the Pallas kernel."""
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    out = pmatmul(x2, w)
    return out.reshape(lead + (w.shape[-1],))


def _attention(x, wq, wk, wv, wo, n_heads):
    b, s, d = x.shape
    hd = d // n_heads
    q = _mm(x, wq).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    k = _mm(x, wk).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    v = _mm(x, wv).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(mask[None, None, :, :] > 0, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return _mm(out, wo)


def _forward(params: Dict[str, jnp.ndarray], tokens_in, cfg: TransformerConfig):
    """Logits ``[B, S, V]`` for input tokens ``[B, S]`` (int32)."""
    h = params["embed"][tokens_in] + params["pos"][None, : tokens_in.shape[1]]
    for i in range(cfg.n_layers):
        p = lambda n: params[f"L{i}.{n}"]
        a = _attention(
            _layernorm(h, p("ln1_scale"), p("ln1_bias")),
            p("wq"), p("wk"), p("wv"), p("wo"), cfg.n_heads,
        )
        h = h + a
        f = _layernorm(h, p("ln2_scale"), p("ln2_bias"))
        f = _mm(f, p("w1"))
        f = jax.nn.gelu(f)
        f = _mm(f, p("w2"))
        h = h + f
    h = _layernorm(h, params["ln_f_scale"], params["ln_f_bias"])
    return _mm(h, params["unembed"])


def make_transformer_step(cfg: TransformerConfig):
    """Build ``step(*params, tokens) -> (loss, *grads)``.

    ``tokens`` is ``[B, S+1]`` f32 (the PS runtime is f32-only); inputs
    are ``tokens[:, :-1]`` and targets ``tokens[:, 1:]``. Loss is mean
    token cross-entropy; grads are in ``param_spec`` order.
    """
    spec = cfg.param_spec()
    names = [n for n, _ in spec]

    def loss_fn(plist, tokens_f):
        params = dict(zip(names, plist))
        tokens = tokens_f.astype(jnp.int32)
        x, t = tokens[:, :-1], tokens[:, 1:]
        logits = _forward(params, x, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, t[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    def step(*args):
        plist = list(args[:-1])
        tokens_f = args[-1]
        loss, grads = jax.value_and_grad(loss_fn)(plist, tokens_f)
        return (loss.reshape(1), *grads)

    return step, spec
