"""L1 Pallas kernels (build-time only; lowered into the L2 HLO).

Modules:
  logreg -- fused logistic-regression gradient (sum reduction)
  lda    -- batched collapsed-Gibbs topic probabilities
  matmul -- MXU-tiled matmul with custom VJP (transformer FLOPs)
  ref    -- pure-jnp oracles for all of the above
"""
