"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

Each function here is the mathematical definition the corresponding
Pallas kernel in this package must reproduce; ``python/tests`` asserts
``assert_allclose(kernel(...), ref(...))`` over randomized shape sweeps.
Keep these maximally simple — no tiling, no tricks.
"""

import jax.numpy as jnp


def logreg_grad_sum(w, x, y):
    """Sum (not mean) logistic-regression gradient and loss.

    grad = sum_i (sigmoid(x_i . w) - y_i) x_i        -- shape [D]
    loss = sum_i softplus(z_i) - y_i z_i             -- scalar

    Returning *sums* makes zero-row padding exact: a padded example with
    x_i = 0 contributes nothing to the gradient and a constant log(2) to
    the loss, which the caller subtracts (it knows the pad count).
    """
    z = x @ w
    p = 1.0 / (1.0 + jnp.exp(-z))
    r = p - y
    grad = x.T @ r
    loss = jnp.sum(jnp.logaddexp(0.0, z) - y * z)
    return grad, loss


def lda_topic_probs(n_wk, n_dk, n_k, alpha, beta, vbeta):
    """Unnormalized collapsed-Gibbs topic probabilities.

    p[b, k] = (n_dk[k] + alpha) * (n_wk[b, k] + beta) / (n_k[k] + vbeta)
    """
    return (n_dk[None, :] + alpha) * (n_wk + beta) / (n_k[None, :] + vbeta)


def matmul(a, b):
    """Plain matrix product (oracle for the tiled Pallas matmul)."""
    return a @ b
