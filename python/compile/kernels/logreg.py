"""L1 Pallas kernel: fused logistic-regression gradient (sum reduction).

The SGD worker's hot spot (paper §3's Theorem-1 workload) is
``grad = X^T (sigmoid(X w) - y)``: two matmuls and an elementwise sigmoid
over the minibatch. On a GPU the paper-era implementation would be a
threadblock-tiled fused kernel; on TPU we express the same fusion with a
Pallas grid over **batch tiles**:

* grid axis 0 walks the batch in ``block_b``-row tiles;
* each step loads an ``[block_b, D]`` tile of X and a ``[block_b]`` slice
  of y into VMEM (BlockSpec index maps express the HBM→VMEM schedule);
* the full weight vector ``w`` (``D ≤ a few thousand``) is replicated in
  VMEM across steps — the analogue of keeping it resident in shared
  memory;
* the tile computes ``x_tile @ w`` on the MXU, the sigmoid + residual on
  the VPU, then accumulates ``x_tile^T r`` into the output ref, which
  Pallas keeps in VMEM across the grid (sequential-grid accumulation).

VMEM budget per step ≈ ``block_b·D + D + block_b`` f32 — with the default
``block_b = 128`` and D up to 4096 that is ≈ 2.1 MiB, comfortably inside
a TPU core's ~16 MiB VMEM (see DESIGN.md §Hardware-Adaptation).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, x_ref, y_ref, grad_ref, loss_ref):
    """One batch tile: accumulate grad += x^T (sigmoid(x w) - y)."""
    step = pl.program_id(0)

    x = x_ref[...]  # [block_b, D]
    w = w_ref[...]  # [D]
    y = y_ref[...]  # [block_b]

    z = x @ w  # MXU: [block_b]
    p = 1.0 / (1.0 + jnp.exp(-z))  # VPU
    r = p - y
    partial_grad = x.T @ r  # MXU: [D]
    # stable softplus(z) - y z, summed over the tile
    partial_loss = jnp.sum(jnp.logaddexp(0.0, z) - y * z)

    @pl.when(step == 0)
    def _init():
        grad_ref[...] = partial_grad
        loss_ref[...] = partial_loss.reshape(loss_ref.shape)

    @pl.when(step != 0)
    def _accum():
        grad_ref[...] += partial_grad
        loss_ref[...] += partial_loss.reshape(loss_ref.shape)


@functools.partial(jax.jit, static_argnames=("block_b",))
def logreg_grad_sum(w, x, y, *, block_b: int = 128):
    """Fused sum-gradient + sum-loss of logistic regression.

    Args:
      w: weights ``[D]`` (f32).
      x: minibatch features ``[B, D]`` with ``B % block_b == 0`` (callers
         pad with zero rows — exact for the gradient, constant ``log 2``
         per pad row for the loss).
      y: labels ``[B]`` in {0, 1}.
      block_b: batch tile height (grid step).

    Returns:
      ``(grad_sum [D], loss_sum [1])``.
    """
    b, d = x.shape
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b {block_b}")
    grid = (b // block_b,)
    grad, loss = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),            # w: replicated
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),  # x: batch tiles
            pl.BlockSpec((block_b,), lambda i: (i,)),      # y: batch tiles
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),            # grad accumulator
            pl.BlockSpec((1,), lambda i: (0,)),            # loss accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(w, x, y)
    return grad, loss
