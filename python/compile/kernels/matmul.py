"""L1 Pallas kernel: MXU-tiled matmul with a custom VJP.

The transformer's FLOPs are matmuls; this kernel expresses them with the
canonical TPU tiling — a 3-D grid over ``(M/bm, N/bn, K/bk)`` where the
K axis is the innermost (sequential) dimension accumulating into the
output tile resident in VMEM. ``bm = bn = bk = 128`` matches the MXU
systolic-array shape, the direct analogue of the paper-era GPU kernels'
``BLOCK_M × BLOCK_N`` shared-memory tiling.

``pallas_call`` is not differentiable, so :func:`pmatmul` carries a
``custom_vjp`` whose backward pass *reuses the same kernel* —
``dA = g @ B^T``, ``dB = A^T @ g`` — keeping every transformer FLOP
(forward and backward) on the L1 path.

VMEM per grid step: 3 tiles × 128×128×4 B = 192 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Tiled ``a @ b`` for 2-D f32 operands whose dims divide the tiles.

    Callers with ragged shapes pad to the tile grid (`aot.py` bakes
    tile-aligned model dims so no padding happens on the hot path).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    if m % bm_ or n % bn_ or k % bk_:
        raise ValueError(f"shape ({m},{k})x({k},{n}) not divisible by tiles")
    grid = (m // bm_, n // bn_, k // bk_)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk_, bn_), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def pmatmul(a, b):
    """Differentiable tiled matmul (backward reuses the Pallas kernel)."""
    return matmul(a, b)


def _pmatmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _pmatmul_bwd(res, g):
    a, b = res
    # dA = g B^T ; dB = A^T g — same kernel, transposed operands.
    da = matmul(g, b.T)
    db = matmul(a.T, g)
    return da, db


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)
