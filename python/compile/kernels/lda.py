"""L1 Pallas kernel: batched collapsed-Gibbs topic probabilities.

For a batch of B tokens of one document, the sampler needs

    p[b, k] = (n_dk[k] + alpha) * (n_wk[b, k] + beta) / (n_k[k] + vbeta)

— pure VPU (elementwise) work over a ``[B, K]`` tile. With the paper's
K = 2000 topics one f32 row is 8 KB, so a ``[block_b, K]`` tile of 64
rows is 512 KB: we block over the batch dimension and keep the shared
``n_dk`` / ``n_k`` rows resident in VMEM across grid steps. K is padded
to the 128-lane boundary by the caller (`aot.py` bakes a lane-aligned K).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(n_wk_ref, n_dk_ref, n_k_ref, alpha_ref, beta_ref, vbeta_ref, out_ref):
    n_wk = n_wk_ref[...]          # [block_b, K]
    n_dk = n_dk_ref[...]          # [K]
    n_k = n_k_ref[...]            # [K]
    alpha = alpha_ref[0]
    beta = beta_ref[0]
    vbeta = vbeta_ref[0]
    out_ref[...] = (n_dk[None, :] + alpha) * (n_wk + beta) / (n_k[None, :] + vbeta)


@functools.partial(jax.jit, static_argnames=("block_b",))
def lda_topic_probs(n_wk, n_dk, n_k, alpha, beta, vbeta, *, block_b: int = 64):
    """Batched unnormalized topic probabilities.

    Args:
      n_wk: word-topic counts for the batch's words, ``[B, K]``.
      n_dk: the document's doc-topic counts, ``[K]``.
      n_k:  global topic sums, ``[K]``.
      alpha, beta, vbeta: scalar priors (``vbeta = V * beta``).
      block_b: batch tile height.

    Returns:
      ``probs [B, K]`` (unnormalized; the sampler normalizes on draw).
    """
    b, k = n_wk.shape
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b {block_b}")
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    beta = jnp.asarray(beta, jnp.float32).reshape(1)
    vbeta = jnp.asarray(vbeta, jnp.float32).reshape(1)
    return pl.pallas_call(
        _kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),  # n_wk: batch tiles
            pl.BlockSpec((k,), lambda i: (0,)),            # n_dk: resident
            pl.BlockSpec((k,), lambda i: (0,)),            # n_k: resident
            pl.BlockSpec((1,), lambda i: (0,)),            # alpha
            pl.BlockSpec((1,), lambda i: (0,)),            # beta
            pl.BlockSpec((1,), lambda i: (0,)),            # vbeta
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(n_wk, n_dk, n_k, alpha, beta, vbeta)
