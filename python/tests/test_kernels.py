"""Kernel-vs-reference correctness: the CORE L1 signal.

Sweeps shapes/dtypes (hypothesis is unavailable offline, so the sweep is
an explicit randomized grid with fixed seeds — same coverage intent) and
asserts the Pallas kernels match the pure-jnp oracles bit-for-bit within
float tolerance.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels import lda, logreg, matmul, ref

RNG = np.random.RandomState(20131231)


# --------------------------------------------------------------------------
# logreg
# --------------------------------------------------------------------------

LOGREG_SHAPES = [
    (128, 8),
    (128, 64),
    (256, 32),
    (512, 64),
    (128, 100),  # non-power-of-two D
    (384, 16),   # 3 grid steps
]


@pytest.mark.parametrize("b,d", LOGREG_SHAPES)
def test_logreg_matches_ref(b, d):
    w = RNG.randn(d).astype(np.float32)
    x = RNG.randn(b, d).astype(np.float32)
    y = (RNG.rand(b) > 0.5).astype(np.float32)
    g, l = logreg.logreg_grad_sum(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y))
    gr, lr = ref.logreg_grad_sum(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(l[0]), float(lr), rtol=2e-4, atol=2e-4)


def test_logreg_zero_row_padding_is_exact():
    d = 16
    w = RNG.randn(d).astype(np.float32)
    x = RNG.randn(96, d).astype(np.float32)
    y = (RNG.rand(96) > 0.5).astype(np.float32)
    # pad to 128 with zero rows / zero labels
    xp = np.zeros((128, d), np.float32)
    xp[:96] = x
    yp = np.zeros((128,), np.float32)
    yp[:96] = y
    g_pad, l_pad = logreg.logreg_grad_sum(jnp.asarray(w), jnp.asarray(xp), jnp.asarray(yp))
    g_ref, l_ref = ref.logreg_grad_sum(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(g_pad), np.asarray(g_ref), rtol=2e-4, atol=2e-4)
    # each pad row adds exactly log(2) to the loss sum
    pad_loss = 32 * np.log(2.0)
    np.testing.assert_allclose(float(l_pad[0]) - pad_loss, float(l_ref), rtol=2e-4, atol=2e-3)


def test_logreg_rejects_ragged_batch():
    with pytest.raises(ValueError):
        logreg.logreg_grad_sum(
            jnp.zeros((4,)), jnp.zeros((100, 4)), jnp.zeros((100,))
        )


def test_logreg_gradient_direction_descends():
    d = 8
    w = np.zeros(d, np.float32)
    x = RNG.randn(256, d).astype(np.float32)
    w_true = RNG.randn(d).astype(np.float32)
    y = (x @ w_true > 0).astype(np.float32)
    for _ in range(30):
        g, _ = logreg.logreg_grad_sum(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y))
        w = w - 0.01 * np.asarray(g)
    acc = float(np.mean((x @ w > 0) == (y > 0.5)))
    assert acc > 0.9, f"descent failed, acc={acc}"


# --------------------------------------------------------------------------
# lda
# --------------------------------------------------------------------------

LDA_SHAPES = [(64, 16), (128, 128), (192, 50), (64, 2000)]


@pytest.mark.parametrize("b,k", LDA_SHAPES)
def test_lda_matches_ref(b, k):
    n_wk = RNG.rand(b, k).astype(np.float32) * 10
    n_dk = RNG.rand(k).astype(np.float32) * 5
    n_k = RNG.rand(k).astype(np.float32) * 100 + 1
    got = lda.lda_topic_probs(
        jnp.asarray(n_wk), jnp.asarray(n_dk), jnp.asarray(n_k), 0.1, 0.01, 535.0
    )
    want = ref.lda_topic_probs(n_wk, n_dk, n_k, 0.1, 0.01, 535.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_lda_probs_positive_and_finite():
    got = lda.lda_topic_probs(
        jnp.zeros((64, 8)), jnp.zeros(8), jnp.zeros(8), 0.1, 0.01, 0.8
    )
    a = np.asarray(got)
    assert np.all(a > 0) and np.all(np.isfinite(a))


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

MM_SHAPES = [
    (128, 128, 128),
    (256, 128, 384),
    (64, 64, 64),     # tiles shrink to dims
    (128, 256, 128),
    (32, 32, 32),
]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
def test_matmul_matches_ref(m, k, n):
    a = RNG.randn(m, k).astype(np.float32)
    b = RNG.randn(k, n).astype(np.float32)
    got = matmul.matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=2e-4, atol=2e-4)


def test_matmul_rejects_ragged():
    # dims ≤ 128 shrink the tile to fit, so raggedness means >128 and not
    # a multiple of the 128 tile.
    with pytest.raises(ValueError):
        matmul.matmul(jnp.zeros((200, 128)), jnp.zeros((128, 128)))


def test_pmatmul_gradients_match_jnp():
    a = RNG.randn(128, 64).astype(np.float32)
    b = RNG.randn(64, 128).astype(np.float32)
    c = RNG.randn(128, 128).astype(np.float32)  # cotangent weighting

    def f_pallas(a_, b_):
        return jnp.sum(matmul.pmatmul(a_, b_) * c)

    def f_ref(a_, b_):
        return jnp.sum((a_ @ b_) * c)

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(jnp.asarray(a), jnp.asarray(b))
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(ga_p), np.asarray(ga_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gb_p), np.asarray(gb_r), rtol=2e-4, atol=2e-4)
