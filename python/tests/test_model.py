"""L2 model correctness: transformer shapes, loss behaviour, grads."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model


def tiny_cfg():
    return model.TransformerConfig(
        vocab=64, d_model=32, n_layers=1, n_heads=2, seq_len=16, batch=2
    )


def init_params(cfg, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in cfg.param_spec():
        if "ln" in name:
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.randn(*shape).astype(np.float32) * 0.05))
    return out


def random_tokens(cfg, seed=1):
    rng = np.random.RandomState(seed)
    return jnp.asarray(
        rng.randint(0, cfg.vocab, size=(cfg.batch, cfg.seq_len + 1)).astype(np.float32)
    )


def test_step_shapes_and_finiteness():
    cfg = tiny_cfg()
    step, spec = model.make_transformer_step(cfg)
    params = init_params(cfg)
    out = step(*params, random_tokens(cfg))
    loss, grads = out[0], out[1:]
    assert loss.shape == (1,)
    assert np.isfinite(float(loss[0]))
    assert len(grads) == len(spec)
    for g, (name, shape) in zip(grads, spec):
        assert g.shape == tuple(shape), f"{name}: {g.shape} != {shape}"
        assert np.all(np.isfinite(np.asarray(g))), f"{name} grad not finite"


def test_initial_loss_near_uniform():
    cfg = tiny_cfg()
    step, _ = model.make_transformer_step(cfg)
    params = init_params(cfg)
    loss = float(step(*params, random_tokens(cfg))[0][0])
    uniform = np.log(cfg.vocab)
    assert abs(loss - uniform) < 0.5, f"loss {loss} vs log V {uniform}"


def test_sgd_on_step_reduces_loss():
    cfg = tiny_cfg()
    step, _ = model.make_transformer_step(cfg)
    jstep = jax.jit(step)
    params = init_params(cfg)
    # deterministic repetitive data: loss must drop fast
    tok = np.tile(np.arange(cfg.seq_len + 1) % 8, (cfg.batch, 1)).astype(np.float32)
    tok = jnp.asarray(tok)
    first = None
    for _ in range(20):
        out = jstep(*params, tok)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss[0])
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    last = float(loss[0])
    assert last < first * 0.5, f"loss did not halve: {first} -> {last}"


def test_param_spec_matches_meta_format():
    cfg = tiny_cfg()
    spec = cfg.param_spec()
    names = [n for n, _ in spec]
    assert names[0] == "embed" and names[-1] == "unembed"
    assert len(names) == len(set(names)), "duplicate param names"
    # every layer contributes 10 tensors
    assert len(names) == 2 + cfg.n_layers * 10 + 3


def test_causal_masking():
    """Changing a future token must not affect earlier logits."""
    cfg = tiny_cfg()
    params = dict(zip([n for n, _ in cfg.param_spec()], init_params(cfg)))
    rng = np.random.RandomState(3)
    x = rng.randint(0, cfg.vocab, size=(1, cfg.seq_len)).astype(np.int32)
    x2 = x.copy()
    x2[0, -1] = (x2[0, -1] + 1) % cfg.vocab
    l1 = model._forward(params, jnp.asarray(x), cfg)
    l2 = model._forward(params, jnp.asarray(x2), cfg)
    np.testing.assert_allclose(
        np.asarray(l1)[0, :-1], np.asarray(l2)[0, :-1], rtol=1e-4, atol=1e-5
    )
    assert not np.allclose(np.asarray(l1)[0, -1], np.asarray(l2)[0, -1])


def test_logreg_model_entry_point():
    w = jnp.zeros((16,), jnp.float32)
    x = jnp.ones((128, 16), jnp.float32)
    y = jnp.ones((128,), jnp.float32)
    g, l = model.logreg_grad(w, x, y)
    assert g.shape == (16,)
    # at w=0: p=0.5, r=-0.5 for y=1 ⇒ grad = -0.5 * col-sums = -64
    np.testing.assert_allclose(np.asarray(g), np.full(16, -64.0), rtol=1e-5)


def test_lda_model_entry_point_is_tuple():
    out = model.lda_topic_probs(
        jnp.ones((64, 8)), jnp.ones(8), jnp.ones(8), 0.1, 0.01, 0.8
    )
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (64, 8)
